//! Minimal JSON reader/writer (no serde in the offline registry).
//!
//! Supports the full JSON value model; numbers are kept as f64 (adequate
//! for configs, manifests and experiment reports). The writer emits
//! stable, deterministic output (sorted object keys optional).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field accessors used by manifest loaders.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing string field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing array field '{key}'"))
    }

    /// Parse a JSON document.
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    // JSON has no NaN/Inf; emit null (documented behaviour).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(ind + 1));
                        item.write(out, Some(ind + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    if !v.is_empty() {
                        out.push('\n');
                        out.push_str(&"  ".repeat(ind));
                    }
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, item)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(ind + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        item.write(out, Some(ind + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        item.write(out, None);
                    }
                }
                if let Some(ind) = indent {
                    if !m.is_empty() {
                        out.push('\n');
                        out.push_str(&"  ".repeat(ind));
                    }
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        // exact up to 2^53 (counters and nanosecond spans in practice);
        // beyond that the nearest-f64 JSON number is the documented
        // behaviour of this f64-backed value model
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // re-decode UTF-8 multibyte sequence
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + width).min(self.bytes.len());
                    if let Ok(chunk) = std::str::from_utf8(&self.bytes[start..end]) {
                        s.push_str(chunk);
                        self.pos = end;
                    } else {
                        return Err(self.err("invalid utf-8"));
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().idx(2).unwrap().as_str(), Some("x\n"));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn roundtrip_pretty() {
        let mut o = Json::obj();
        o.set("name", "pico".into())
            .set("dims", vec![1usize, 2, 3].into())
            .set("lr", 0.001.into())
            .set("flag", true.into());
        let pretty = o.to_pretty();
        let re = Json::parse(&pretty).unwrap();
        assert_eq!(o, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
        let v2 = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v2.as_str(), Some("héllo"));
    }

    #[test]
    fn integers_stay_integral() {
        let v = Json::Num(42.0);
        assert_eq!(v.to_string(), "42");
        let v = Json::Num(42.5);
        assert_eq!(v.to_string(), "42.5");
    }

    #[test]
    fn u64_counters_roundtrip_integral() {
        // telemetry counters (step indexes, nanosecond spans) are u64
        let v: Json = 1_234_567_890_123u64.into();
        assert_eq!(v.to_string(), "1234567890123");
        assert_eq!(Json::parse(&v.to_string()).unwrap().as_f64(), Some(1_234_567_890_123.0));
        let zero: Json = 0u64.into();
        assert_eq!(zero.to_string(), "0");
    }

    #[test]
    fn nested_deep() {
        let mut s = String::new();
        for _ in 0..50 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..50 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }
}
