//! Small subcommand-style CLI parser (no `clap` in the offline registry).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors, defaults and generated usage text.

use std::collections::BTreeMap;

/// Declarative option spec used for usage text.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding the binary name). The first token not
    /// starting with `-` becomes the subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    out.options.insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let val = iter.next().unwrap();
                    out.options.insert(rest.to_string(), val);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.command.is_none() && out.positional.is_empty() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.options.get(name).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u32_or(&self, name: &str, default: u32) -> u32 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated usize list, e.g. `--tiles 64,128`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(s) => s.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }

    /// Comma-separated string list.
    pub fn str_list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(s) => s.split(',').map(|t| t.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

/// Render usage text for a command table.
pub fn usage(binary: &str, about: &str, commands: &[(&str, &str)], opts: &[OptSpec]) -> String {
    let mut s = format!("{binary} — {about}\n\nUSAGE:\n  {binary} <COMMAND> [OPTIONS]\n\nCOMMANDS:\n");
    for (name, help) in commands {
        s.push_str(&format!("  {name:<18} {help}\n"));
    }
    if !opts.is_empty() {
        s.push_str("\nOPTIONS:\n");
        for o in opts {
            let d = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{:<16} {}{}\n", o.name, o.help, d));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["quantize", "--model", "pico-160k", "--bits=4", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("quantize"));
        assert_eq!(a.get("model"), Some("pico-160k"));
        assert_eq!(a.usize_or("bits", 8), 4);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn lists() {
        let a = parse(&["bench", "--tiles", "64,128", "--models", "a, b,c"]);
        assert_eq!(a.usize_list_or("tiles", &[32]), vec![64, 128]);
        assert_eq!(a.str_list_or("models", &[]), vec!["a", "b", "c"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&["eval"]);
        assert_eq!(a.str_or("model", "x"), "x");
        assert_eq!(a.f64_or("lr", 0.1), 0.1);
        assert_eq!(a.usize_list_or("tiles", &[32, 64]), vec![32, 64]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["run", "--fast"]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn positional_args() {
        let a = parse(&["audit", "file1", "file2", "--deep"]);
        assert_eq!(a.positional, vec!["file1", "file2"]);
        assert!(a.flag("deep"));
    }
}
