//! Calibration-time graph conditioning (paper App. C.1):
//!
//! - **SmoothQuant** (Xiao et al.) for the LM family: per-channel
//!   difficulty migration from activations into weights at
//!   LayerNorm→Linear boundaries, folded into the LN affine parameters.
//! - **Weight equalization** (Nagel et al.) for the MLP family:
//!   scale-balancing consecutive linear layers through positively
//!   homogeneous activations (ReLU).
//! - **Bias correction** (Nagel et al.): absorb the systematic output
//!   shift E[Wx] − E[Qx̃] into the layer bias after quantization.

use crate::linalg::Mat;
use crate::model::{FloatLinear, LayerNorm, Linear, QuantLinear};

/// Per-input-channel max |x| from a K×D calibration capture.
pub fn channel_abs_max(x_kd: &Mat) -> Vec<f64> {
    (0..x_kd.rows())
        .map(|i| x_kd.row(i).iter().fold(0.0f64, |m, v| m.max(v.abs())))
        .collect()
}

/// Per-output-channel max |w| of a float linear ([out, in] layout).
fn weight_col_abs_max(l: &FloatLinear) -> Vec<f64> {
    // max over outputs for each input column j
    let mut m = vec![0.0f64; l.in_dim];
    for o in 0..l.out_dim {
        let row = &l.w()[o * l.in_dim..(o + 1) * l.in_dim];
        for (j, &w) in row.iter().enumerate() {
            m[j] = m[j].max(w.abs() as f64);
        }
    }
    m
}

/// SmoothQuant at a LayerNorm → {linears} boundary: compute per-channel
/// s_j = max|x_j|^α / max|w_j|^{1−α}, divide the LN affine by s, multiply
/// the consuming linears' input columns by s. Exact (no approximation).
/// Returns the applied scales.
pub fn smoothquant_fold(
    ln: &mut LayerNorm,
    consumers: &mut [&mut Linear],
    act_max: &[f64],
    alpha: f64,
) -> Vec<f64> {
    let k = act_max.len();
    assert_eq!(ln.gamma.len(), k);
    // aggregate weight max across all consumers
    let mut w_max = vec![0.0f64; k];
    for c in consumers.iter() {
        let fl = c.as_float().expect("smoothquant requires float consumers");
        assert_eq!(fl.in_dim, k);
        for (j, m) in weight_col_abs_max(fl).into_iter().enumerate() {
            w_max[j] = w_max[j].max(m);
        }
    }
    let scales: Vec<f64> = (0..k)
        .map(|j| {
            let a = act_max[j].max(1e-8);
            let w = w_max[j].max(1e-8);
            (a.powf(alpha) / w.powf(1.0 - alpha)).clamp(1e-4, 1e4)
        })
        .collect();
    for j in 0..k {
        ln.gamma[j] /= scales[j] as f32;
        ln.beta[j] /= scales[j] as f32;
    }
    for c in consumers.iter_mut() {
        if let Linear::Float(fl) = c {
            let (in_dim, out_dim) = (fl.in_dim, fl.out_dim);
            // one w_mut borrow per layer: bumps the widened-cache
            // version exactly once for the whole rescale
            let w = fl.w_mut();
            for o in 0..out_dim {
                for j in 0..k {
                    w[o * in_dim + j] *= scales[j] as f32;
                }
            }
        }
    }
    scales
}

/// Nagel-style weight equalization between consecutive linears l1 → act
/// → l2 (valid for positively homogeneous activations): balance output
/// channel j of l1 with input column j of l2 using s_j = √(r1_j / r2_j).
pub fn equalize_pair(l1: &mut FloatLinear, l2: &mut FloatLinear) -> Vec<f64> {
    assert_eq!(l1.out_dim, l2.in_dim);
    let c = l1.out_dim;
    let mut scales = vec![1.0f64; c];
    for j in 0..c {
        let r1 = l1.w()[j * l1.in_dim..(j + 1) * l1.in_dim]
            .iter()
            .fold(0.0f32, |m, v| m.max(v.abs())) as f64;
        let mut r2 = 0.0f64;
        for o in 0..l2.out_dim {
            r2 = r2.max(l2.w()[o * l2.in_dim + j].abs() as f64);
        }
        if r1 > 1e-9 && r2 > 1e-9 {
            scales[j] = (r1 / r2).sqrt().clamp(1e-4, 1e4);
        }
    }
    let in1 = l1.in_dim;
    {
        let w1 = l1.w_mut();
        for j in 0..c {
            let s = scales[j] as f32;
            for w in &mut w1[j * in1..(j + 1) * in1] {
                *w /= s;
            }
        }
    }
    for j in 0..c {
        l1.b[j] /= scales[j] as f32;
    }
    let (in2, out2) = (l2.in_dim, l2.out_dim);
    let w2 = l2.w_mut();
    for j in 0..c {
        let s = scales[j] as f32;
        for o in 0..out2 {
            w2[o * in2 + j] *= s;
        }
    }
    scales
}

/// Bias correction: mean float output (from the float weights and float
/// inputs) minus mean quantized output (quantized weights on
/// quantized-prefix inputs), added to the quantized layer's bias.
///
/// * `w_float` — K×C original float weights.
/// * `x_float` — K×D float-model calibration inputs.
/// * `xt` — K×D quantized-prefix calibration inputs.
pub fn bias_correct(q: &mut QuantLinear, w_float: &Mat, x_float: &Mat, xt: &Mat) {
    let (k, c) = (w_float.rows(), w_float.cols());
    assert_eq!(q.in_dim, k);
    assert_eq!(q.out_dim, c);
    let d = x_float.cols();
    // mean float input / mean quantized-prefix input per neuron
    let mean_x: Vec<f64> = (0..k).map(|i| x_float.row(i).iter().sum::<f64>() / d as f64).collect();
    // float mean output (excluding bias): W^T mean_x
    let mut float_mean = vec![0.0f64; c];
    for i in 0..k {
        for ch in 0..c {
            float_mean[ch] += w_float.get(i, ch) * mean_x[i];
        }
    }
    // quantized mean output (excluding bias): run the integer path on
    // each calibration column of xt and average.
    let mut qmean = vec![0.0f64; c];
    let mut xrow = vec![0.0f32; k];
    let mut yrow = vec![0.0f32; c];
    let mut scratch = vec![0i64; k];
    let saved_bias = q.bias.clone();
    for b in &mut q.bias {
        *b = 0.0;
    }
    for s in 0..d {
        for i in 0..k {
            xrow[i] = xt.get(i, s) as f32;
        }
        q.forward_row(&xrow, &mut yrow, &mut scratch);
        for ch in 0..c {
            qmean[ch] += yrow[ch] as f64;
        }
    }
    q.bias = saved_bias;
    for ch in 0..c {
        qmean[ch] /= d as f64;
        q.bias[ch] += (float_mean[ch] - qmean[ch]) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Datapath;
    use crate::quant::ActQuantizer as AQ;
    use crate::quant::{gpfq_quantize, GpfqParams};
    use crate::util::rng::Rng;

    #[test]
    fn channel_abs_max_works() {
        let m = Mat::from_vec(2, 3, vec![1.0, -4.0, 2.0, 0.5, 0.2, -0.1]);
        assert_eq!(channel_abs_max(&m), vec![4.0, 0.5]);
    }

    #[test]
    fn smoothquant_preserves_function() {
        let mut rng = Rng::new(110);
        let k = 8;
        let mut ln = LayerNorm::new(
            (0..k).map(|_| 1.0 + rng.f32() * 0.5).collect(),
            (0..k).map(|_| rng.normal() as f32 * 0.1).collect(),
        );
        let w: Vec<f32> = (0..k * 4).map(|_| rng.normal() as f32).collect();
        let mut lin = Linear::Float(FloatLinear::new(k, 4, w, vec![0.0; 4]));
        // reference output
        let x: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        let mut ln_out = vec![0.0f32; k];
        ln.forward_row(&x, &mut ln_out);
        let mut y_ref = vec![0.0f32; 4];
        let mut scratch = Vec::new();
        lin.forward_row(&ln_out, &mut y_ref, &mut scratch);
        // fold with synthetic act stats
        let act_max: Vec<f64> = (0..k).map(|j| 1.0 + j as f64).collect();
        let scales = smoothquant_fold(&mut ln, &mut [&mut lin], &act_max, 0.5);
        assert!(scales.iter().all(|&s| s > 0.0));
        // function must be unchanged
        ln.forward_row(&x, &mut ln_out);
        let mut y_new = vec![0.0f32; 4];
        lin.forward_row(&ln_out, &mut y_new, &mut scratch);
        for (a, b) in y_ref.iter().zip(y_new.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn equalize_pair_preserves_relu_function() {
        let mut rng = Rng::new(111);
        let mut l1 = FloatLinear::new(
            4,
            6,
            (0..24).map(|_| rng.normal() as f32).collect(),
            (0..6).map(|_| rng.normal() as f32 * 0.1).collect(),
        );
        let mut l2 = FloatLinear::new(
            6,
            3,
            (0..18).map(|_| rng.normal() as f32 * 3.0).collect(),
            vec![0.0; 3],
        );
        let x: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
        let fwd = |l1: &FloatLinear, l2: &FloatLinear| {
            let mut h = vec![0.0f32; 6];
            l1.forward_row(&x, &mut h);
            for v in &mut h {
                *v = v.max(0.0);
            }
            let mut y = vec![0.0f32; 3];
            l2.forward_row(&h, &mut y);
            y
        };
        let y_ref = fwd(&l1, &l2);
        equalize_pair(&mut l1, &mut l2);
        let y_new = fwd(&l1, &l2);
        for (a, b) in y_ref.iter().zip(y_new.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        // ranges are balanced after equalization
        let r1: Vec<f32> = (0..6)
            .map(|j| l1.w()[j * 4..(j + 1) * 4].iter().fold(0.0f32, |m, v| m.max(v.abs())))
            .collect();
        let r2: Vec<f32> = (0..6)
            .map(|j| (0..3).map(|o| l2.w()[o * 6 + j].abs()).fold(0.0f32, f32::max))
            .collect();
        for j in 0..6 {
            assert!((r1[j] - r2[j]).abs() / r1[j].max(1e-6) < 1e-3, "channel {j} unbalanced");
        }
    }

    #[test]
    fn bias_correction_reduces_output_shift() {
        let mut rng = Rng::new(112);
        let k = 32;
        let c = 8;
        let d = 64;
        let w = Mat::random_normal(k, c, &mut rng, 0.4);
        // inputs with non-zero mean make quantization bias visible
        let x = Mat::from_fn(k, d, |_, _| rng.normal() + 0.8);
        let r = gpfq_quantize(&w, &x, &x, &GpfqParams::base(3, 8));
        let samples: Vec<f64> = x.data().to_vec();
        let act = AQ::calibrate(&samples, 8, 0.999);
        let mk = || {
            QuantLinear::from_result(&r, vec![0.0; c], act, Datapath::Exact)
        };
        // shift before correction
        let shift = |q: &QuantLinear| -> f64 {
            let mut total = 0.0;
            let mut xrow = vec![0.0f32; k];
            let mut yrow = vec![0.0f32; c];
            let mut scratch = vec![0i64; k];
            let mut float_y = vec![0.0f64; c];
            let mut qy = vec![0.0f64; c];
            for s in 0..d {
                for i in 0..k {
                    xrow[i] = x.get(i, s) as f32;
                }
                q.forward_row(&xrow, &mut yrow, &mut scratch);
                for ch in 0..c {
                    qy[ch] += yrow[ch] as f64;
                    let mut f = 0.0;
                    for i in 0..k {
                        f += w.get(i, ch) * x.get(i, s);
                    }
                    float_y[ch] += f;
                }
            }
            for ch in 0..c {
                total += (float_y[ch] / d as f64 - qy[ch] / d as f64).abs();
            }
            total
        };
        let q0 = mk();
        let before = shift(&q0);
        let mut q1 = mk();
        bias_correct(&mut q1, &w, &x, &x);
        let after = shift(&q1);
        assert!(after < before * 0.2 + 1e-9, "before={before} after={after}");
    }
}
