//! Perplexity evaluation for the pico-LM family (the paper's WikiText2
//! metric). Sequences are evaluated in parallel across threads; the
//! model is shared read-only.

use crate::model::{softmax, Transformer};

/// Perplexity evaluation summary.
#[derive(Clone, Debug)]
pub struct PplReport {
    pub ppl: f64,
    pub nll: f64,
    pub tokens: usize,
    /// Overflow events observed in quantized layers during the run.
    pub overflows: u64,
}

/// Compute perplexity of `model` over non-overlapping sequences of
/// length `seq` from `tokens`, using at most `max_seqs` sequences.
pub fn perplexity(model: &Transformer, tokens: &[u16], seq: usize, max_seqs: usize) -> PplReport {
    let seqs: Vec<&[u16]> = tokens.chunks_exact(seq).take(max_seqs).collect();
    assert!(!seqs.is_empty(), "not enough tokens for one sequence");
    let before = model.overflow_events();
    let nthreads = crate::linalg::num_threads().min(seqs.len()).max(1);
    let chunk = seqs.len().div_ceil(nthreads);
    let mut partials: Vec<(f64, usize)> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..nthreads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(seqs.len());
            if lo >= hi {
                continue;
            }
            let my = &seqs[lo..hi];
            handles.push(scope.spawn(move || {
                let mut nll = 0.0f64;
                let mut count = 0usize;
                for s in my {
                    let (n, c) = seq_nll(model, s);
                    nll += n;
                    count += c;
                }
                (nll, count)
            }));
        }
        for h in handles {
            partials.push(h.join().expect("ppl worker panicked"));
        }
    });
    let nll: f64 = partials.iter().map(|p| p.0).sum();
    let count: usize = partials.iter().map(|p| p.1).sum();
    let mean = nll / count.max(1) as f64;
    PplReport {
        ppl: mean.exp(),
        nll: mean,
        tokens: count,
        overflows: model.overflow_events() - before,
    }
}

/// Summed next-token NLL over one sequence.
fn seq_nll(model: &Transformer, s: &[u16]) -> (f64, usize) {
    let vocab = model.cfg.vocab;
    let logits = model.forward(s, None);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    // predict token t+1 from position t
    let mut probs = vec![0.0f32; vocab];
    for t in 0..s.len() - 1 {
        probs.copy_from_slice(&logits[t * vocab..(t + 1) * vocab]);
        softmax(&mut probs);
        let p = probs[s[t + 1] as usize].max(1e-12);
        nll -= (p as f64).ln();
        count += 1;
    }
    (nll, count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::dataset::synth_corpus;
    use crate::model::{random_transformer, Activation, TransformerConfig};

    fn tiny() -> Transformer {
        random_transformer(
            TransformerConfig {
                name: "t".into(),
                vocab: 64,
                d_model: 16,
                n_layers: 1,
                n_heads: 2,
                d_ff: 32,
                max_seq: 24,
                act: Activation::Gelu,
                parallel_residual: false,
            },
            9,
        )
    }

    #[test]
    fn random_model_near_uniform_ppl() {
        let m = tiny();
        let toks = synth_corpus(24 * 8, 64, 11);
        let r = perplexity(&m, &toks, 24, 8);
        // near-random weights -> ppl close to vocab size
        assert!(r.ppl > 20.0 && r.ppl < 200.0, "ppl={}", r.ppl);
        assert_eq!(r.tokens, 8 * 23);
        assert_eq!(r.overflows, 0);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let m = tiny();
        let toks = synth_corpus(24 * 6, 64, 12);
        let a = perplexity(&m, &toks, 24, 6);
        std::env::set_var("AXE_THREADS_IGNORED", "1"); // threads only split work
        let b = perplexity(&m, &toks, 24, 6);
        assert!((a.nll - b.nll).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "not enough tokens")]
    fn too_short_panics() {
        let m = tiny();
        perplexity(&m, &[1, 2, 3], 24, 4);
    }
}
