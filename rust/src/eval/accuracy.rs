//! Top-1 classification accuracy for the glyph MLP family (the paper's
//! ImageNet metric).

use super::dataset::GlyphSet;
use crate::model::Mlp;

/// Top-1 accuracy (%) of `model` on `set`, evaluated thread-parallel.
pub fn top1_accuracy(model: &Mlp, set: &GlyphSet) -> f64 {
    let n = set.len();
    assert!(n > 0);
    let nthreads = crate::linalg::num_threads().min(n).max(1);
    let chunk = n.div_ceil(nthreads);
    let mut partials: Vec<usize> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..nthreads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                continue;
            }
            handles.push(scope.spawn(move || {
                let mut correct = 0usize;
                for i in lo..hi {
                    let logits = model.forward(set.row(i), None);
                    let pred = argmax(&logits);
                    if pred == set.y[i] as usize {
                        correct += 1;
                    }
                }
                correct
            }));
        }
        for h in handles {
            partials.push(h.join().expect("accuracy worker panicked"));
        }
    });
    100.0 * partials.iter().sum::<usize>() as f64 / n as f64
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::dataset::synth_glyphs;
    use crate::model::{random_mlp, Activation, MlpConfig};

    #[test]
    fn random_model_near_chance() {
        let set = synth_glyphs(200, 8, 10, 20);
        let m = random_mlp(
            MlpConfig {
                name: "t".into(),
                input_dim: 64,
                hidden: vec![32],
                classes: 10,
                act: Activation::Relu,
                residual: false,
            },
            21,
        );
        let acc = top1_accuracy(&m, &set);
        assert!(acc < 40.0, "untrained model should be near chance, got {acc}");
    }

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0]), 0);
        assert_eq!(argmax(&[1.0, 1.0]), 0, "ties keep first");
    }
}
