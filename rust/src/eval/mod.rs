//! Evaluation: datasets, perplexity, classification accuracy.

pub mod accuracy;
pub mod dataset;
pub mod perplexity;

pub use accuracy::top1_accuracy;
pub use dataset::{
    load_corpus, load_corpus_split, load_corpus_split_or_synth, load_glyphs, synth_corpus,
    synth_glyphs, GlyphSet,
};
pub use perplexity::{perplexity, PplReport};
