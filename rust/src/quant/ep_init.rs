//! EP-init — the Euclidean-projection baseline (Colbert et al. A2Q+,
//! applied post-training per paper §2.3 / App. C.1).
//!
//! EP-init projects each channel's (dequantized) weights onto the ℓ1
//! ball whose radius is the accumulator budget, then re-quantizes with
//! **round-to-zero** so that |Q(w_i)| ≤ |w_i| for all i, which preserves
//! the ℓ1 bound through quantization. It is a vector-wise operation with
//! no error correction — exactly the shortcoming AXE addresses.
//!
//! In the PTQ pipeline it is applied *after* GPFQ/OPTQ (so their error
//! correction still contributed) and *before* bias correction.

use super::axe::AccumTarget;
use super::bounds::side_budget;
use super::l1::project_l1;
use super::quantizer::Rounding;
use super::result::QuantResult;

/// Apply EP-init to an already-quantized layer, returning a new
/// `QuantResult` that is guaranteed safe for `target` against unsigned
/// `act_bits` inputs.
pub fn ep_init(result: &QuantResult, target: AccumTarget, act_bits: u32) -> QuantResult {
    let (p_bits, tile) = match target.tile_plan(result.k) {
        Some(plan) => plan,
        None => return result.clone(),
    };
    // Budget: EP-init enforces the zero-centered ℓ1 bound of Eq. 4. We
    // use the one-sided-safe budget 2B with RTZ slack 0, which implies
    // both Eq. 7 and Eq. 8 regardless of centering (‖q‖₁ ≤ 2B ⇒ each of
    // β, −α ≤ 2B... note: β ≤ ‖q‖₁; safety needs β ≤ B' = (2^{P−1}−1)/(2^N−1),
    // so the correct radius for arbitrary-centered vectors is B', not 2B').
    let budget = side_budget(p_bits, act_bits, Rounding::Zero.max_delta());
    let mut out = result.clone();
    for ch in 0..result.c {
        let w_scaled: Vec<f64> = (0..result.k).map(|i| result.code(i, ch) as f64).collect();
        for t in 0..result.k.div_ceil(tile) {
            let lo = t * tile;
            let hi = ((t + 1) * tile).min(result.k);
            let proj = project_l1(&w_scaled[lo..hi], budget);
            for (off, &v) in proj.v.iter().enumerate() {
                // round-to-zero keeps |code| ≤ |v| so the ℓ1 bound holds
                out.set_code(lo + off, ch, Rounding::Zero.round(v) as i64);
            }
        }
    }
    out
}

/// EP-init applied directly to float weights (the "initialization" use):
/// project w/s per channel, then RTZ-quantize. Used when no base
/// algorithm runs first.
pub fn ep_init_float(
    w: &crate::linalg::Mat,
    weight_bits: u32,
    target: AccumTarget,
    act_bits: u32,
) -> QuantResult {
    let wq = super::quantizer::WeightQuantizer::fit_columns(w, weight_bits, Rounding::Zero);
    let (k, c) = (w.rows(), w.cols());
    let mut out = QuantResult::new(k, c, weight_bits, wq.scales.clone());
    let plan = target.tile_plan(k);
    for ch in 0..c {
        let s = wq.scales[ch];
        let w_scaled: Vec<f64> = (0..k).map(|i| w.get(i, ch) / s).collect();
        match plan {
            None => {
                for i in 0..k {
                    out.set_code(i, ch, wq.to_code_scaled(w_scaled[i]));
                }
            }
            Some((p_bits, tile)) => {
                let budget = side_budget(p_bits, act_bits, 0.0);
                for t in 0..k.div_ceil(tile) {
                    let lo = t * tile;
                    let hi = ((t + 1) * tile).min(k);
                    let proj = project_l1(&w_scaled[lo..hi], budget);
                    for (off, &v) in proj.v.iter().enumerate() {
                        out.set_code(lo + off, ch, wq.to_code_scaled(v));
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::quant::bounds::{is_safe, is_safe_multistage};
    use crate::quant::gpfq::{gpfq_quantize, GpfqParams};
    use crate::util::rng::Rng;

    fn quantized_fixture(seed: u64) -> QuantResult {
        let mut rng = Rng::new(seed);
        let w = Mat::random_normal(64, 6, &mut rng, 0.5);
        let x = Mat::random_normal(64, 128, &mut rng, 1.0);
        gpfq_quantize(&w, &x, &x, &GpfqParams::base(6, 8))
    }

    #[test]
    fn unconstrained_target_is_identity() {
        let r = quantized_fixture(60);
        let e = ep_init(&r, AccumTarget::None, 8);
        assert_eq!(r.codes, e.codes);
    }

    #[test]
    fn monolithic_projection_is_safe() {
        let r = quantized_fixture(61);
        let e = ep_init(&r, AccumTarget::Monolithic { p_bits: 13 }, 8);
        for ch in 0..e.c {
            assert!(is_safe(&e.channel_codes(ch), 0, 255, 13), "ch={ch}");
        }
    }

    #[test]
    fn multistage_projection_is_safe() {
        let r = quantized_fixture(62);
        let e = ep_init(&r, AccumTarget::MultiStage { p_inner: 11, tile: 16 }, 8);
        for ch in 0..e.c {
            assert!(is_safe_multistage(&e.channel_codes(ch), 0, 255, 11, 16), "ch={ch}");
        }
    }

    #[test]
    fn projection_only_shrinks_magnitudes() {
        let r = quantized_fixture(63);
        let e = ep_init(&r, AccumTarget::Monolithic { p_bits: 13 }, 8);
        for (q_new, q_old) in e.codes.iter().zip(r.codes.iter()) {
            assert!(q_new.abs() <= q_old.abs(), "EP-init must not grow codes");
            assert!(q_new.signum() == q_old.signum() || *q_new == 0);
        }
    }

    #[test]
    fn ep_init_float_is_safe() {
        let mut rng = Rng::new(64);
        let w = Mat::random_normal(48, 4, &mut rng, 0.8);
        let e = ep_init_float(&w, 4, AccumTarget::Monolithic { p_bits: 12 }, 8);
        for ch in 0..4 {
            assert!(is_safe(&e.channel_codes(ch), 0, 255, 12));
            assert!(e.max_abs_code() <= 7);
        }
    }

    #[test]
    fn ep_init_increases_sparsity_under_tight_budget() {
        let r = quantized_fixture(65);
        let e = ep_init(&r, AccumTarget::Monolithic { p_bits: 12 }, 8);
        assert!(e.sparsity() >= r.sparsity(), "projection zeroes small codes");
    }
}
