//! Output container shared by all layer-wise quantization algorithms.

use crate::linalg::Mat;

/// Quantized weights for one layer: integer codes plus per-channel scales.
/// Layout matches the input weight matrix: K×C (input index × channel).
#[derive(Clone, Debug)]
pub struct QuantResult {
    pub k: usize,
    pub c: usize,
    pub bits: u32,
    /// K×C row-major integer codes in the signed alphabet A_M.
    pub codes: Vec<i64>,
    /// Per-channel scale s_c (Eq. 27).
    pub scales: Vec<f64>,
}

impl QuantResult {
    pub fn new(k: usize, c: usize, bits: u32, scales: Vec<f64>) -> QuantResult {
        assert_eq!(scales.len(), c);
        QuantResult { k, c, bits, codes: vec![0; k * c], scales }
    }

    #[inline]
    pub fn code(&self, i: usize, ch: usize) -> i64 {
        self.codes[i * self.c + ch]
    }

    #[inline]
    pub fn set_code(&mut self, i: usize, ch: usize, q: i64) {
        self.codes[i * self.c + ch] = q;
    }

    /// Codes of a single channel (length K).
    pub fn channel_codes(&self, ch: usize) -> Vec<i64> {
        (0..self.k).map(|i| self.code(i, ch)).collect()
    }

    /// Dequantized weight matrix (K×C).
    pub fn dequant(&self) -> Mat {
        Mat::from_fn(self.k, self.c, |i, ch| self.code(i, ch) as f64 * self.scales[ch])
    }

    /// Fraction of zero codes (the paper reports unstructured sparsity).
    pub fn sparsity(&self) -> f64 {
        let zeros = self.codes.iter().filter(|&&q| q == 0).count();
        zeros as f64 / self.codes.len().max(1) as f64
    }

    /// ℓ1 norm of a channel's codes.
    pub fn channel_l1(&self, ch: usize) -> f64 {
        (0..self.k).map(|i| self.code(i, ch).abs() as f64).sum()
    }

    /// Per-channel sum of codes (needed for the zero-point correction
    /// term at inference).
    pub fn channel_sums(&self) -> Vec<i64> {
        let mut sums = vec![0i64; self.c];
        for i in 0..self.k {
            for ch in 0..self.c {
                sums[ch] += self.code(i, ch);
            }
        }
        sums
    }

    /// Largest |code| (must stay within the alphabet).
    pub fn max_abs_code(&self) -> i64 {
        self.codes.iter().map(|q| q.abs()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dequant_and_sparsity() {
        let mut r = QuantResult::new(3, 2, 4, vec![0.5, 2.0]);
        r.set_code(0, 0, 3);
        r.set_code(2, 1, -1);
        let w = r.dequant();
        assert_eq!(w.get(0, 0), 1.5);
        assert_eq!(w.get(2, 1), -2.0);
        assert_eq!(w.get(1, 1), 0.0);
        assert!((r.sparsity() - 4.0 / 6.0).abs() < 1e-12);
        assert_eq!(r.channel_sums(), vec![3, -1]);
        assert_eq!(r.max_abs_code(), 3);
        assert_eq!(r.channel_l1(0), 3.0);
        assert_eq!(r.channel_codes(1), vec![0, 0, -1]);
    }
}
