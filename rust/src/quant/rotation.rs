//! Randomized block-Hadamard rotation — the paper's §5 future-work
//! extension (QuaRot / SpinQuant-style incoherence preprocessing),
//! implemented as an optional pipeline stage.
//!
//! R = H_b · D with H_b a normalized block-Hadamard (largest power-of-two
//! block dividing K) and D a seeded ±1 diagonal. R is orthogonal, so
//! rotating both the weights (W' = Rᵀ W) and the activations (x' = Rᵀ x)
//! leaves every dot product unchanged in exact arithmetic while
//! flattening activation outliers — which is exactly what per-tensor
//! activation quantizers and the AXE ℓ1 budgets like. The online
//! transform costs O(K log b) per row via the fast Walsh–Hadamard
//! transform.

use crate::util::rng::Rng;

/// A seeded randomized block-Hadamard rotation for dimension `k`.
#[derive(Clone, Debug)]
pub struct Rotation {
    pub k: usize,
    /// Power-of-two Hadamard block edge (1 disables mixing).
    pub block: usize,
    /// ±1 diagonal (applied before the Hadamard mix).
    pub signs: Vec<f32>,
}

/// Largest power of two dividing `k`.
pub fn hadamard_block(k: usize) -> usize {
    if k == 0 {
        return 1;
    }
    1usize << k.trailing_zeros()
}

impl Rotation {
    /// Deterministic rotation for dimension `k` from a seed.
    pub fn new(k: usize, seed: u64) -> Rotation {
        let mut rng = Rng::new(seed ^ 0x6A09_E667_F3BC_C908);
        let signs = (0..k).map(|_| if rng.chance(0.5) { 1.0 } else { -1.0 }).collect();
        Rotation { k, block: hadamard_block(k), signs }
    }

    /// Apply x' = Rᵀ x = H (D x) in place (f32 row).
    pub fn apply_row(&self, x: &mut [f32]) {
        debug_assert_eq!(x.len(), self.k);
        for (v, s) in x.iter_mut().zip(self.signs.iter()) {
            *v *= s;
        }
        fwht_blocks(x, self.block);
    }

    /// Inverse: x = R x' = D (H x') (H is an involution when normalized).
    pub fn apply_row_inverse(&self, x: &mut [f32]) {
        debug_assert_eq!(x.len(), self.k);
        fwht_blocks(x, self.block);
        for (v, s) in x.iter_mut().zip(self.signs.iter()) {
            *v *= s;
        }
    }

    /// Rotate a K×C weight matrix in place: W' = Rᵀ W (each column is a
    /// K-vector treated like an activation row).
    pub fn apply_weights_kc(&self, w: &mut crate::linalg::Mat) {
        assert_eq!(w.rows(), self.k);
        let c = w.cols();
        let mut col = vec![0.0f32; self.k];
        for ch in 0..c {
            for i in 0..self.k {
                col[i] = w.get(i, ch) as f32;
            }
            self.apply_row(&mut col);
            for i in 0..self.k {
                w.set(i, ch, col[i] as f64);
            }
        }
    }

    /// Rotate a K×D capture matrix in place (each sample column).
    pub fn apply_capture_kd(&self, x: &mut crate::linalg::Mat) {
        assert_eq!(x.rows(), self.k);
        let d = x.cols();
        let mut col = vec![0.0f32; self.k];
        for s in 0..d {
            for i in 0..self.k {
                col[i] = x.get(i, s) as f32;
            }
            self.apply_row(&mut col);
            for i in 0..self.k {
                x.set(i, s, col[i] as f64);
            }
        }
    }
}

/// In-place normalized fast Walsh–Hadamard transform applied per
/// contiguous block of `block` elements (block must be a power of two).
pub fn fwht_blocks(x: &mut [f32], block: usize) {
    debug_assert!(block.is_power_of_two());
    if block <= 1 {
        return;
    }
    let norm = 1.0 / (block as f32).sqrt();
    for chunk in x.chunks_mut(block) {
        if chunk.len() < block {
            continue; // trailing partial block left unmixed
        }
        let mut h = 1;
        while h < block {
            let mut i = 0;
            while i < block {
                for j in i..i + h {
                    let a = chunk[j];
                    let b = chunk[j + h];
                    chunk[j] = a + b;
                    chunk[j + h] = a - b;
                }
                i += h * 2;
            }
            h *= 2;
        }
        for v in chunk.iter_mut() {
            *v *= norm;
        }
    }
}

/// Excess kurtosis of a sample — the outlier metric rotation flattens.
pub fn kurtosis(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
    if var < 1e-18 {
        return 0.0;
    }
    let m4 = xs.iter().map(|x| (x - mean).powi(4)).sum::<f64>() / n;
    m4 / (var * var) - 3.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::prop::quick;

    #[test]
    fn hadamard_block_values() {
        assert_eq!(hadamard_block(224), 32);
        assert_eq!(hadamard_block(64), 64);
        assert_eq!(hadamard_block(56), 8);
        assert_eq!(hadamard_block(7), 1);
        assert_eq!(hadamard_block(0), 1);
    }

    #[test]
    fn fwht_is_involution_and_isometry() {
        quick(
            "fwht_involution",
            |rng| {
                let block = 1usize << rng.int_in(1, 6);
                let n = block * rng.int_in(1, 4) as usize;
                let xs: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                (xs, block)
            },
            |(xs, block)| {
                let mut y = xs.clone();
                fwht_blocks(&mut y, *block);
                let n_before: f32 = xs.iter().map(|v| v * v).sum();
                let n_after: f32 = y.iter().map(|v| v * v).sum();
                if (n_before - n_after).abs() > 1e-3 * n_before.max(1.0) {
                    return Err(format!("not an isometry: {n_before} vs {n_after}"));
                }
                fwht_blocks(&mut y, *block);
                for (a, b) in xs.iter().zip(y.iter()) {
                    if (a - b).abs() > 1e-4 {
                        return Err("not an involution".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn rotation_roundtrip() {
        let r = Rotation::new(48, 7);
        let mut x: Vec<f32> = (0..48).map(|i| (i as f32 - 20.0) * 0.3).collect();
        let orig = x.clone();
        r.apply_row(&mut x);
        r.apply_row_inverse(&mut x);
        for (a, b) in x.iter().zip(orig.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn rotation_preserves_dot_products() {
        // Rᵀ on both sides of a dot product is a no-op (orthogonality).
        let k = 64;
        let r = Rotation::new(k, 3);
        let mut rng = crate::util::rng::Rng::new(5);
        let mut w = Mat::random_normal(k, 4, &mut rng, 0.5);
        let x: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        // reference dot per channel
        let dots: Vec<f64> =
            (0..4).map(|c| (0..k).map(|i| w.get(i, c) * x[i] as f64).sum()).collect();
        r.apply_weights_kc(&mut w);
        let mut xr = x.clone();
        r.apply_row(&mut xr);
        for c in 0..4 {
            let d: f64 = (0..k).map(|i| w.get(i, c) * xr[i] as f64).sum();
            assert!((d - dots[c]).abs() < 1e-3, "channel {c}: {d} vs {}", dots[c]);
        }
    }

    #[test]
    fn rotation_flattens_outliers() {
        // a spiky activation vector (few huge channels) must become much
        // flatter after rotation — the QuaRot effect.
        let k = 256;
        let r = Rotation::new(k, 11);
        let mut rng = crate::util::rng::Rng::new(13);
        let mut worst_before = 0.0f64;
        let mut worst_after = 0.0f64;
        for _ in 0..10 {
            let mut x: Vec<f32> = (0..k).map(|_| rng.normal() as f32 * 0.1).collect();
            // inject outliers
            for _ in 0..3 {
                x[rng.below(k)] = 50.0;
            }
            let before: Vec<f64> = x.iter().map(|&v| v as f64).collect();
            let mut xr = x.clone();
            r.apply_row(&mut xr);
            let after: Vec<f64> = xr.iter().map(|&v| v as f64).collect();
            worst_before = worst_before.max(kurtosis(&before));
            worst_after = worst_after.max(kurtosis(&after));
        }
        assert!(
            worst_after < worst_before / 2.0,
            "kurtosis must drop: {worst_before:.1} -> {worst_after:.1}"
        );
    }

    #[test]
    fn capture_rotation_consistent_with_row_rotation() {
        let k = 32;
        let r = Rotation::new(k, 21);
        let mut rng = crate::util::rng::Rng::new(22);
        let mut m = Mat::random_normal(k, 5, &mut rng, 1.0);
        let col0: Vec<f32> = (0..k).map(|i| m.get(i, 0) as f32).collect();
        r.apply_capture_kd(&mut m);
        let mut expected = col0;
        r.apply_row(&mut expected);
        for i in 0..k {
            assert!((m.get(i, 0) as f32 - expected[i]).abs() < 1e-4);
        }
    }
}
