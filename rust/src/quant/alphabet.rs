//! Fixed-point integer alphabets A_b (paper §2).
//!
//! Signed alphabets use the sign-magnitude convention of the paper:
//! A_b = {k ∈ ℤ : −(2^{b−1}−1) ≤ k ≤ 2^{b−1}−1}. Unsigned alphabets are
//! [0, 2^b − 1] (the asymmetric-activation case of §3.2 with μ=0,
//! ν=2^N−1).

/// An integer quantization alphabet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Alphabet {
    pub bits: u32,
    pub signed: bool,
}

impl Alphabet {
    pub fn signed(bits: u32) -> Alphabet {
        assert!((1..=32).contains(&bits), "bits must be in 1..=32");
        Alphabet { bits, signed: true }
    }

    pub fn unsigned(bits: u32) -> Alphabet {
        assert!((1..=32).contains(&bits), "bits must be in 1..=32");
        Alphabet { bits, signed: false }
    }

    /// Smallest representable value.
    #[inline]
    pub fn min_val(&self) -> i64 {
        if self.signed {
            -(self.max_val())
        } else {
            0
        }
    }

    /// Largest representable value.
    #[inline]
    pub fn max_val(&self) -> i64 {
        if self.signed {
            (1i64 << (self.bits - 1)) - 1
        } else {
            (1i64 << self.bits) - 1
        }
    }

    /// Number of representable levels.
    pub fn levels(&self) -> i64 {
        self.max_val() - self.min_val() + 1
    }

    /// Range width ν − μ (used by the overflow bound, §3.1).
    pub fn width(&self) -> i64 {
        self.max_val() - self.min_val()
    }

    #[inline]
    pub fn contains(&self, v: i64) -> bool {
        v >= self.min_val() && v <= self.max_val()
    }

    /// Clamp an integer into the alphabet.
    #[inline]
    pub fn clamp(&self, v: i64) -> i64 {
        v.clamp(self.min_val(), self.max_val())
    }

    /// Clamp a real value into the alphabet's real hull.
    #[inline]
    pub fn clamp_f(&self, v: f64) -> f64 {
        v.clamp(self.min_val() as f64, self.max_val() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signed_ranges() {
        let a = Alphabet::signed(4);
        assert_eq!(a.min_val(), -7);
        assert_eq!(a.max_val(), 7);
        assert_eq!(a.levels(), 15);
        assert_eq!(a.width(), 14);
        let a8 = Alphabet::signed(8);
        assert_eq!(a8.max_val(), 127);
        assert_eq!(a8.min_val(), -127); // sign-magnitude
    }

    #[test]
    fn unsigned_ranges() {
        let a = Alphabet::unsigned(8);
        assert_eq!(a.min_val(), 0);
        assert_eq!(a.max_val(), 255);
        assert_eq!(a.levels(), 256);
        let a3 = Alphabet::unsigned(3);
        assert_eq!(a3.max_val(), 7);
    }

    #[test]
    fn clamp_behaviour() {
        let a = Alphabet::signed(3); // [-3, 3]
        assert_eq!(a.clamp(10), 3);
        assert_eq!(a.clamp(-10), -3);
        assert_eq!(a.clamp(2), 2);
        assert!(a.contains(0));
        assert!(!a.contains(4));
        assert_eq!(a.clamp_f(3.7), 3.0);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_bits() {
        Alphabet::signed(0);
    }
}
