//! Euclidean projection onto the ℓ1 ball (Duchi et al., 2008) and the
//! Lagrangian soft-threshold used by AXE (paper Eq. 13-16).
//!
//! Given weights w and budget Z, the projection's Lagrange multiplier is
//!   λ = (Σ_{i≤ρ} μ_i − Z)/ρ        (Eq. 16)
//! where μ is |w| sorted descending and ρ the number of surviving
//! non-zeros. AXE then applies the soft-threshold operator
//!   Π_λ(x) = sign(x)·(|x| − λ)₊     (paper, after Eq. 13)
//! greedily inside the PTQ iteration rather than as a one-shot projection.

/// Soft-threshold (shrinkage) operator Π_λ.
#[inline]
pub fn soft_threshold(x: f64, lambda: f64) -> f64 {
    let m = x.abs() - lambda;
    if m > 0.0 {
        m * x.signum()
    } else {
        0.0
    }
}

/// Result of the ℓ1-ball projection.
#[derive(Clone, Debug)]
pub struct L1Projection {
    /// Projected vector (‖v‖₁ ≤ z).
    pub v: Vec<f64>,
    /// Lagrange multiplier λ (0 when already inside the ball).
    pub lambda: f64,
    /// Number of non-zeros in the projection.
    pub rho: usize,
}

/// Project `w` onto the ℓ1 ball of radius `z ≥ 0` (Duchi et al. 2008,
/// Fig. 1 algorithm — O(K log K)).
pub fn project_l1(w: &[f64], z: f64) -> L1Projection {
    assert!(z >= 0.0, "l1 radius must be non-negative");
    let norm1: f64 = w.iter().map(|x| x.abs()).sum();
    if norm1 <= z {
        return L1Projection { v: w.to_vec(), lambda: 0.0, rho: w.iter().filter(|x| x.abs() > 0.0).count() };
    }
    if z == 0.0 {
        return L1Projection { v: vec![0.0; w.len()], lambda: f64::INFINITY, rho: 0 };
    }
    let mut mu: Vec<f64> = w.iter().map(|x| x.abs()).collect();
    mu.sort_by(|a, b| b.partial_cmp(a).unwrap());
    // find ρ = max{ j : μ_j − (Σ_{r≤j} μ_r − z)/j > 0 }
    let mut cumsum = 0.0;
    let mut rho = 0usize;
    let mut cum_at_rho = 0.0;
    for (j, &m) in mu.iter().enumerate() {
        cumsum += m;
        if m - (cumsum - z) / (j + 1) as f64 > 0.0 {
            rho = j + 1;
            cum_at_rho = cumsum;
        }
    }
    let lambda = (cum_at_rho - z) / rho as f64;
    let v: Vec<f64> = w.iter().map(|&x| soft_threshold(x, lambda)).collect();
    L1Projection { v, lambda, rho }
}

/// Only the Lagrangian λ for budget `z` (Eq. 16) — what AXE feeds Π_λ.
pub fn derive_lambda(w: &[f64], z: f64) -> f64 {
    project_l1(w, z).lambda
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quick;
    use crate::util::rng::Rng;

    fn l1(v: &[f64]) -> f64 {
        v.iter().map(|x| x.abs()).sum()
    }

    #[test]
    fn inside_ball_is_identity() {
        let w = vec![0.5, -0.25, 0.1];
        let p = project_l1(&w, 2.0);
        assert_eq!(p.v, w);
        assert_eq!(p.lambda, 0.0);
    }

    #[test]
    fn projection_hits_boundary() {
        let w = vec![3.0, -4.0, 1.0];
        let p = project_l1(&w, 2.0);
        assert!((l1(&p.v) - 2.0).abs() < 1e-9);
        assert!(p.lambda > 0.0);
    }

    #[test]
    fn zero_radius_zeroes_everything() {
        let w = vec![1.0, -2.0];
        let p = project_l1(&w, 0.0);
        assert!(p.v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn soft_threshold_shrinks() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
    }

    #[test]
    fn known_projection() {
        // project [2, 1] onto z=1: λ solves... μ=[2,1]; ρ=1: 2-(2-1)/1=1>0 ✓;
        // ρ=2: 1-(3-1)/2=0 not >0. so ρ=1, λ=(2-1)/1=1 → v=[1, 0]
        let p = project_l1(&[2.0, 1.0], 1.0);
        assert!((p.v[0] - 1.0).abs() < 1e-12);
        assert_eq!(p.v[1], 0.0);
        assert_eq!(p.rho, 1);
        assert!((p.lambda - 1.0).abs() < 1e-12);
    }

    #[test]
    fn prop_projection_satisfies_budget_and_optimality() {
        quick(
            "l1_projection",
            |rng: &mut Rng| {
                let k = rng.int_in(1, 64) as usize;
                let w = rng.normal_vec(k);
                let z = rng.range_f64(0.0, 10.0);
                (w, z)
            },
            |(w, z)| {
                let p = project_l1(w, *z);
                if l1(&p.v) > z + 1e-9 {
                    return Err(format!("budget violated: {} > {z}", l1(&p.v)));
                }
                // optimality vs a few random feasible candidates
                let d0: f64 = w.iter().zip(&p.v).map(|(a, b)| (a - b) * (a - b)).sum();
                let mut rng2 = Rng::new(7);
                for _ in 0..20 {
                    // random candidate inside the ball
                    let mut c: Vec<f64> = w.iter().map(|_| rng2.normal()).collect();
                    let n = l1(&c);
                    if n > *z && n > 0.0 {
                        let f = z / n;
                        for v in &mut c {
                            *v *= f;
                        }
                    }
                    let d: f64 = w.iter().zip(&c).map(|(a, b)| (a - b) * (a - b)).sum();
                    if d < d0 - 1e-7 {
                        return Err(format!("candidate beats projection: {d} < {d0}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_projection_idempotent() {
        quick(
            "l1_idempotent",
            |rng: &mut Rng| {
                let k = rng.int_in(1, 32) as usize;
                (rng.normal_vec(k), rng.range_f64(0.1, 5.0))
            },
            |(w, z)| {
                let p1 = project_l1(w, *z);
                let p2 = project_l1(&p1.v, *z);
                for (a, b) in p1.v.iter().zip(p2.v.iter()) {
                    if (a - b).abs() > 1e-9 {
                        return Err("not idempotent".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn lambda_matches_eq16_interpretation() {
        // λ = average gap between surviving magnitudes and the budget
        let w = vec![5.0, 3.0, 0.1];
        let z = 4.0;
        let p = project_l1(&w, z);
        // surviving: |5|,|3| → ρ=2, λ=(8−4)/2=2 → v=[3,1,0], ‖v‖₁=4 ✓
        assert_eq!(p.rho, 2);
        assert!((p.lambda - 2.0).abs() < 1e-12);
        assert!((l1(&p.v) - 4.0).abs() < 1e-12);
    }
}
