//! The accumulator arithmetic bounds from the paper.
//!
//! - Eq. 3: the data-type bound P* — the minimum accumulator width that
//!   is safe for *any* weights in A_M and inputs in A_N of depth K.
//! - Eq. 4: the ℓ1 budget ‖q‖₁ ≤ (2^P − 2)/(2^N − 1) (zero-centered).
//! - Eq. 17/21: the one-sided budgets A, B with rounding slack max(Δ).
//! - Eq. 22: multi-stage outer width P_O = ⌈P_I + log2 K − log2 T⌉.

/// Eq. 3 — minimum accumulator bit width guaranteeing overflow avoidance
/// from the operand data types alone. `signed_input` is the indicator
/// 1_signed(x̃).
pub fn datatype_min_bits(k: usize, n_bits: u32, m_bits: u32, signed_input: bool) -> u32 {
    assert!(k >= 1);
    // inner = 2^{log2(K) + N + M - 1 - 1_signed} = K * 2^{N+M-1-s}
    let s = if signed_input { 1 } else { 0 };
    let shift = n_bits + m_bits - 1 - s;
    let inner: u128 = (k as u128) << shift;
    // P* = ceil( log2(inner + 1) + 1 ) = ceil(log2(inner + 1)) + 1
    ceil_log2_u128(inner + 1) + 1
}

/// ⌈log2(v)⌉ for v ≥ 1.
pub fn ceil_log2_u128(v: u128) -> u32 {
    assert!(v >= 1);
    if v == 1 {
        return 0;
    }
    128 - (v - 1).leading_zeros()
}

/// Eq. 4 — ℓ1-norm budget for a zero-centered weight vector accumulated
/// with N-bit (unsigned-range) inputs into a signed P-bit register.
pub fn l1_budget(p_bits: u32, n_bits: u32) -> f64 {
    assert!(p_bits >= 2);
    ((1u128 << p_bits) - 2) as f64 / ((1u128 << n_bits) - 1) as f64
}

/// Eq. 21 — strict one-sided budget B (and A = −B) in integer-code units,
/// including the worst-case rounding slack `max_delta` (0.5 for RTN, 0
/// for RTZ). Returns the budget for *one side* (sum of positive codes ≤ B;
/// −sum of negative codes ≤ B).
pub fn side_budget(p_bits: u32, n_bits: u32, max_delta: f64) -> f64 {
    assert!(p_bits >= 2);
    let b = ((1u128 << (p_bits - 1)) - 1) as f64 / ((1u128 << n_bits) - 1) as f64;
    (b - max_delta).max(0.0)
}

/// Eq. 22 — outer accumulator width for multi-stage accumulation of a
/// K-deep dot product computed in tiles of size T, each tile guaranteed
/// within a P_I-bit inner accumulator.
pub fn outer_bits(p_inner: u32, k: usize, tile: usize) -> u32 {
    assert!(tile >= 1 && k >= 1);
    if k <= tile {
        return p_inner;
    }
    // ceil(P_I + log2(K) - log2(T)); number of tiles = ceil(K/T), and the
    // worst case is ceil(log2(#tiles)) extra bits.
    let ratio = (k as f64) / (tile as f64);
    (p_inner as f64 + ratio.log2()).ceil() as u32
}

/// Exact worst-case accumulator value reachable by weights `q` (integer
/// codes) against inputs in [mu, nu] (Eq. 6-8). Returns (max, min).
pub fn worst_case_range(q: &[i64], mu: i64, nu: i64) -> (i128, i128) {
    let mut hi: i128 = 0;
    let mut lo: i128 = 0;
    for &qi in q {
        let q = qi as i128;
        if qi >= 0 {
            hi += q * nu as i128;
            lo += q * mu as i128;
        } else {
            hi += q * mu as i128;
            lo += q * nu as i128;
        }
    }
    (hi, lo)
}

/// Whether integer weights `q` are safe for a signed `p_bits` accumulator
/// against any input codes in [mu, nu] (sign-magnitude convention: the
/// register holds values in ±(2^{P−1}−1)).
pub fn is_safe(q: &[i64], mu: i64, nu: i64, p_bits: u32) -> bool {
    let cap = ((1i128 << (p_bits - 1)) - 1) as i128;
    let (hi, lo) = worst_case_range(q, mu, nu);
    hi <= cap && -lo <= cap
}

/// Safe inner-accumulator width for the quantized-KV **attention**
/// matmuls. Unlike the linear layers, both attention operands are
/// data-dependent codes (the K/V cache carries no AXE-trained
/// weight-side ℓ1 guarantee), so the only a-priori bound is the
/// data-type bound (Eq. 3) evaluated at the tile depth. Conservative
/// over both attention matmuls — score (signed query codes × signed key
/// codes) and value (unsigned probability codes × signed value codes) —
/// by taking the unsigned-input case, which needs one bit more.
pub fn attention_inner_bits(tile: usize, op_bits: u32, kv_bits: u32) -> u32 {
    datatype_min_bits(tile, op_bits, kv_bits, false)
}

/// Whether weights are safe under multi-stage accumulation: every tile of
/// size `tile` within a P_I-bit inner register, and the exact total within
/// the implied P_O-bit outer register.
pub fn is_safe_multistage(q: &[i64], mu: i64, nu: i64, p_inner: u32, tile: usize) -> bool {
    for chunk in q.chunks(tile) {
        if !is_safe(chunk, mu, nu, p_inner) {
            return false;
        }
    }
    let p_outer = outer_bits(p_inner, q.len(), tile);
    is_safe(q, mu, nu, p_outer)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quick;
    use crate::util::rng::Rng;

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2_u128(1), 0);
        assert_eq!(ceil_log2_u128(2), 1);
        assert_eq!(ceil_log2_u128(3), 2);
        assert_eq!(ceil_log2_u128(4), 2);
        assert_eq!(ceil_log2_u128(5), 3);
        assert_eq!(ceil_log2_u128(1 << 40), 40);
    }

    #[test]
    fn datatype_bound_known_values() {
        // W4A8 with K=128, unsigned acts: P* = ceil(log2(128 * 2^{8+4-1} + 1)) + 1
        //   = ceil(log2(2^7 * 2^11 + 1)) + 1 = ceil(log2(2^18+1)) + 1 = 19 + 1 = 20
        assert_eq!(datatype_min_bits(128, 8, 4, false), 20);
        // paper §4.2: "P_I* = 20 when T = 128 for W4A8 via Eq. 3" ✓
        assert_eq!(datatype_min_bits(64, 8, 4, false), 19);
    }

    #[test]
    fn datatype_bound_monotone() {
        let mut prev = 0;
        for logk in 0..12 {
            let p = datatype_min_bits(1usize << logk, 8, 4, false);
            assert!(p >= prev);
            prev = p;
        }
        assert!(datatype_min_bits(64, 8, 8, false) > datatype_min_bits(64, 8, 4, false));
        assert!(
            datatype_min_bits(64, 8, 4, true) <= datatype_min_bits(64, 8, 4, false),
            "signed inputs need no more bits"
        );
    }

    #[test]
    fn datatype_bound_is_sufficient_and_near_tight() {
        // The worst-case dot of K maximal products must fit in P* bits.
        // Eq. 3 is derived for the full two's-complement operand range,
        // so with sign-magnitude alphabets it can be conservative by one
        // bit — but never by two.
        for &(k, n, m) in &[(4usize, 4u32, 3u32), (16, 8, 4), (7, 5, 5), (128, 8, 4)] {
            let p = datatype_min_bits(k, n, m, false);
            let wmax = (1i64 << (m - 1)) - 1;
            let numax = (1i64 << n) - 1;
            let q = vec![wmax; k];
            assert!(is_safe(&q, 0, numax, p), "k={k} n={n} m={m} P*={p}");
            assert!(!is_safe(&q, 0, numax, p - 2), "P*-2 must overflow (k={k} n={n} m={m})");
        }
    }

    #[test]
    fn l1_budget_matches_eq4() {
        assert!((l1_budget(16, 8) - (65534.0 / 255.0)).abs() < 1e-9);
        assert!((l1_budget(8, 8) - (254.0 / 255.0)).abs() < 1e-9);
    }

    #[test]
    fn side_budget_subtracts_slack() {
        let b_rtn = side_budget(16, 8, 0.5);
        let b_rtz = side_budget(16, 8, 0.0);
        assert!((b_rtz - 32767.0 / 255.0).abs() < 1e-9);
        assert!((b_rtz - b_rtn - 0.5).abs() < 1e-9);
        assert_eq!(side_budget(2, 8, 10.0), 0.0); // floor at zero
    }

    #[test]
    fn outer_bits_known() {
        // paper Table 1 context: K=10240, T=64, P_I=16 -> P_O = 16 + log2(160) ≈ 23.3 -> 24
        assert_eq!(outer_bits(16, 10240, 64), 24);
        assert_eq!(outer_bits(16, 64, 64), 16);
        assert_eq!(outer_bits(16, 128, 64), 17);
        assert_eq!(outer_bits(16, 32, 64), 16); // K < T
    }

    #[test]
    fn side_budget_guarantees_safety() {
        // Any integer q with per-side sums within side_budget is safe.
        quick(
            "side_budget_safe",
            |rng: &mut Rng| {
                let p = rng.int_in(8, 20) as u32;
                let n = rng.int_in(2, 8) as u32;
                let k = rng.int_in(4, 256) as usize;
                let b = side_budget(p, n, 0.0);
                // fill greedily within budget
                let mut pos = 0.0;
                let mut neg = 0.0;
                let mut q = Vec::with_capacity(k);
                for _ in 0..k {
                    let v = rng.int_in(-15, 15);
                    if v >= 0 && pos + v as f64 <= b {
                        pos += v as f64;
                        q.push(v);
                    } else if v < 0 && neg + (-v) as f64 <= b {
                        neg += (-v) as f64;
                        q.push(v);
                    } else {
                        q.push(0);
                    }
                }
                (q, n, p)
            },
            |(q, n, p)| {
                let nu = (1i64 << n) - 1;
                if is_safe(q, 0, nu, *p) {
                    Ok(())
                } else {
                    Err(format!("q within budget overflowed P={p} N={n}"))
                }
            },
        );
    }

    #[test]
    fn attention_inner_bits_is_sufficient() {
        // 8-bit operands on both sides at tile 64:
        //   inner = 64 · 2^{8+8-1} = 2^21 → P* = 22 + 1 = 23
        assert_eq!(attention_inner_bits(64, 8, 8), 23);
        // the bound must cover the adversarial tile: maximal unsigned
        // inputs against maximal-magnitude signed codes
        for &(tile, op, kv) in &[(64usize, 8u32, 8u32), (128, 8, 8), (64, 8, 16), (16, 8, 4)] {
            let p = attention_inner_bits(tile, op, kv);
            let wmax = (1i64 << (kv - 1)) - 1;
            let numax = (1i64 << op) - 1;
            let q = vec![wmax; tile];
            assert!(is_safe(&q, 0, numax, p), "tile={tile} op={op} kv={kv} P={p}");
        }
    }

    #[test]
    fn multistage_safety_decomposes() {
        let q = vec![3i64; 128];
        // each 64-tile: 3*64*255 = 48960 <= 2^16/2-1? 32767 — no. Use smaller.
        let q_small = vec![1i64; 128];
        // tile sum = 64*255 = 16320 <= 32767 ✓ (P_I=16); outer P_O=17 cap 65535 ≥ 32640 ✓
        assert!(is_safe_multistage(&q_small, 0, 255, 16, 64));
        assert!(!is_safe_multistage(&q, 0, 255, 16, 64));
    }

    #[test]
    fn worst_case_range_signs() {
        let q = vec![2, -3];
        let (hi, lo) = worst_case_range(&q, 0, 10);
        assert_eq!(hi, 20); // 2*10 + (-3)*0
        assert_eq!(lo, -30); // 2*0 + (-3)*10
    }
}
