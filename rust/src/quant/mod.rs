//! The quantization core: uniform quantizers, the accumulator bounds,
//! the AXE constraint machinery, and the layer-wise PTQ algorithms
//! (GPFQ, OPTQ) with accumulator-aware variants, plus the EP-init and
//! naïve bit-width-manipulation baselines.

pub mod alphabet;
pub mod axe;
pub mod bounds;
pub mod ep_init;
pub mod gpfq;
pub mod l1;
pub mod optq;
pub mod quantizer;
pub mod result;
pub mod rotation;

pub use alphabet::Alphabet;
pub use axe::{AccumTarget, AxeConfig};
pub use bounds::{
    attention_inner_bits, datatype_min_bits, is_safe, is_safe_multistage, l1_budget, outer_bits,
    side_budget,
};
pub use ep_init::{ep_init, ep_init_float};
pub use gpfq::{gpfq_quantize, gpfq_quantize_grams, GpfqParams};
pub use l1::{derive_lambda, project_l1, soft_threshold};
pub use optq::{optq_quantize, OptqParams};
pub use quantizer::{ActQuantizer, Rounding, WeightQuantizer};
pub use result::QuantResult;
pub use rotation::Rotation;

/// Which base PTQ algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Gpfq,
    /// Memory-efficient GPFQ (Theorem B.1) — identical output, O(K²) memory.
    GpfqMemEff,
    Optq,
}

impl Algorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Gpfq => "GPFQ",
            Algorithm::GpfqMemEff => "GPFQ*",
            Algorithm::Optq => "OPTQ",
        }
    }

    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_ascii_lowercase().as_str() {
            "gpfq" => Some(Algorithm::Gpfq),
            "gpfq*" | "gpfq-mem" | "gpfqmemeff" | "gpfq_mem" => Some(Algorithm::GpfqMemEff),
            "optq" | "gptq" => Some(Algorithm::Optq),
            _ => None,
        }
    }
}

/// How accumulator-awareness is enforced on top of the base algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Base algorithm only; accumulator sized by the data-type bound Eq. 3.
    Naive,
    /// Base algorithm, then EP-init projection (round-to-zero).
    EpInit,
    /// AXE greedy constraints inside the base algorithm.
    Axe,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Naive => "naive",
            Method::EpInit => "ep-init",
            Method::Axe => "axe",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "naive" | "base" => Some(Method::Naive),
            "ep-init" | "epinit" | "ep_init" => Some(Method::EpInit),
            "axe" => Some(Method::Axe),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_algorithms_and_methods() {
        assert_eq!(Algorithm::parse("gptq"), Some(Algorithm::Optq));
        assert_eq!(Algorithm::parse("GPFQ"), Some(Algorithm::Gpfq));
        assert_eq!(Algorithm::parse("gpfq*"), Some(Algorithm::GpfqMemEff));
        assert_eq!(Algorithm::parse("nope"), None);
        assert_eq!(Method::parse("AXE"), Some(Method::Axe));
        assert_eq!(Method::parse("ep-init"), Some(Method::EpInit));
        assert_eq!(Method::parse("base"), Some(Method::Naive));
    }
}
