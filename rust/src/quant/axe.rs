//! AXE — the accumulator-aware constraint machinery (paper §3.2-3.3).
//!
//! Two ingredients, both operating in the *integer-code domain* (w/s):
//!
//! 1. a **soft ℓ1 penalty**: the soft-threshold Π_λ with λ derived per
//!    channel (per tile in the multi-stage case) from the Euclidean
//!    projection onto the ℓ1 ball of radius Z = (2^P − 2)/(2^N − 1)
//!    (Eq. 15-16); and
//! 2. a **strict running clip** Ψ_{a,b}: the remaining positive budget
//!    b = B − β_i and negative budget a = A − α_i are tracked as codes
//!    are committed (Eq. 18-21), so the worst-case dot product against
//!    any unsigned N-bit input can never leave ±(2^{P−1}−1).
//!
//! `Monolithic` applies one budget per channel; `MultiStage` applies the
//! budget per contiguous tile of `tile` input indices — tiles are
//! *physical* (defined on original input positions), so act-order
//! permutations in the base algorithm do not change tile membership.

use super::bounds::{outer_bits, side_budget};
use super::l1::derive_lambda;
use super::quantizer::Rounding;

/// What accumulator the quantization must be safe for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccumTarget {
    /// Unconstrained base algorithm (GPFQ/OPTQ as published).
    None,
    /// One P-bit accumulator per dot product (A2Q-style).
    Monolithic { p_bits: u32 },
    /// Tiled datapath: each tile of `tile` inputs accumulates in a
    /// P_I-bit inner register; partial sums in the implied outer register
    /// (Eq. 22).
    MultiStage { p_inner: u32, tile: usize },
}

impl AccumTarget {
    pub fn is_constrained(&self) -> bool {
        !matches!(self, AccumTarget::None)
    }

    /// Effective (per-tile width, tile size) for a K-deep dot product.
    pub fn tile_plan(&self, k: usize) -> Option<(u32, usize)> {
        match *self {
            AccumTarget::None => None,
            AccumTarget::Monolithic { p_bits } => Some((p_bits, k.max(1))),
            AccumTarget::MultiStage { p_inner, tile } => Some((p_inner, tile.min(k.max(1)))),
        }
    }

    /// Outer accumulator width needed at inference for depth `k`.
    pub fn outer_bits(&self, k: usize) -> Option<u32> {
        match *self {
            AccumTarget::None => None,
            AccumTarget::Monolithic { p_bits } => Some(p_bits),
            AccumTarget::MultiStage { p_inner, tile } => Some(outer_bits(p_inner, k, tile)),
        }
    }

    pub fn describe(&self) -> String {
        match *self {
            AccumTarget::None => "base".to_string(),
            AccumTarget::Monolithic { p_bits } => format!("P{p_bits}"),
            AccumTarget::MultiStage { p_inner, tile } => format!("{tile}x{p_inner}b"),
        }
    }
}

/// Full AXE configuration attached to a base PTQ algorithm.
#[derive(Clone, Copy, Debug)]
pub struct AxeConfig {
    pub target: AccumTarget,
    /// Soft ℓ1 penalty on (off = AXE-HCO ablation).
    pub soft: bool,
    /// Rounding function of the weight quantizer — sets max(Δ) in Eq. 21.
    pub rounding: Rounding,
    /// Activation bit width N (inputs assumed unsigned asymmetric codes).
    pub act_bits: u32,
}

impl AxeConfig {
    pub fn unconstrained(rounding: Rounding, act_bits: u32) -> AxeConfig {
        AxeConfig { target: AccumTarget::None, soft: false, rounding, act_bits }
    }

    pub fn monolithic(p_bits: u32, act_bits: u32) -> AxeConfig {
        AxeConfig {
            target: AccumTarget::Monolithic { p_bits },
            soft: true,
            rounding: Rounding::Nearest,
            act_bits,
        }
    }

    pub fn multistage(p_inner: u32, tile: usize, act_bits: u32) -> AxeConfig {
        AxeConfig {
            target: AccumTarget::MultiStage { p_inner, tile },
            soft: true,
            rounding: Rounding::Nearest,
            act_bits,
        }
    }
}

/// Per-channel running constraint state for one quantization pass.
///
/// All quantities are in integer-code units. `a[t] ≤ 0 ≤ b[t]` always
/// holds, so a zero code is always admissible and the greedy pass can
/// never get stuck.
#[derive(Clone, Debug)]
pub struct ConstraintState {
    tile: usize,
    /// Per-tile λ for Π_λ (zeros when soft penalty disabled).
    lambdas: Vec<f64>,
    /// Remaining negative budget per tile (≤ 0).
    a: Vec<f64>,
    /// Remaining positive budget per tile (≥ 0).
    b: Vec<f64>,
    /// max(Δ) of the rounding function — the budget may legitimately go
    /// negative by up to this amount (Eq. 21 reserves the slack).
    slack: f64,
}

impl ConstraintState {
    /// Build the state for one channel. `w_scaled` is the channel's
    /// weight vector divided by its quantizer scale (length K). Returns
    /// `None` for the unconstrained target.
    pub fn new(cfg: &AxeConfig, w_scaled: &[f64]) -> Option<ConstraintState> {
        let k = w_scaled.len();
        let (p_bits, tile) = cfg.target.tile_plan(k)?;
        let n_tiles = k.div_ceil(tile);
        let budget = side_budget(p_bits, cfg.act_bits, cfg.rounding.max_delta());
        let mut lambdas = vec![0.0; n_tiles];
        if cfg.soft {
            // Z per tile: the zero-centered ℓ1 budget of Eq. 4 for the
            // tile's accumulator. Using the two-sided budget 2B keeps the
            // projection target consistent with the strict constraint.
            let z = 2.0 * budget;
            for (t, lam) in lambdas.iter_mut().enumerate() {
                let lo = t * tile;
                let hi = ((t + 1) * tile).min(k);
                *lam = derive_lambda(&w_scaled[lo..hi], z);
            }
        }
        Some(ConstraintState {
            tile,
            lambdas,
            a: vec![-budget; n_tiles],
            b: vec![budget; n_tiles],
            slack: cfg.rounding.max_delta(),
        })
    }

    #[inline]
    fn tile_of(&self, i: usize) -> usize {
        i / self.tile
    }

    /// Apply Π_λ then Ψ_{a,b} to the pre-quantization value of input
    /// index `i` (original position) in code units.
    #[inline]
    pub fn process(&self, i: usize, v_scaled: f64) -> f64 {
        let t = self.tile_of(i);
        let v = super::l1::soft_threshold(v_scaled, self.lambdas[t]);
        // Rounding slack can overshoot a side's budget by up to max(Δ);
        // once a side is exhausted only zero remains admissible there.
        v.clamp(self.a[t].min(0.0), self.b[t].max(0.0))
    }

    /// Commit the chosen integer code for input index `i`, consuming
    /// budget.
    #[inline]
    pub fn commit(&mut self, i: usize, q: i64) {
        let t = self.tile_of(i);
        if q >= 0 {
            self.b[t] -= q as f64;
            // Rounding may overshoot the clipped value by up to max(Δ);
            // once negative, only zero/negative codes remain admissible on
            // this side, so the total β stays ≤ B + max(Δ) = exact cap.
            debug_assert!(self.b[t] >= -self.slack - 1e-9, "positive budget violated");
        } else {
            self.a[t] -= q as f64; // q < 0 ⇒ a moves toward 0
            debug_assert!(self.a[t] <= self.slack + 1e-9, "negative budget violated");
        }
    }

    /// Remaining budgets of the tile containing `i` (for tests/telemetry).
    pub fn remaining(&self, i: usize) -> (f64, f64) {
        let t = self.tile_of(i);
        (self.a[t], self.b[t])
    }

    pub fn lambda(&self, i: usize) -> f64 {
        self.lambdas[self.tile_of(i)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bounds::is_safe;
    use crate::util::prop::quick;
    use crate::util::rng::Rng;

    #[test]
    fn unconstrained_has_no_state() {
        let cfg = AxeConfig::unconstrained(Rounding::Nearest, 8);
        assert!(ConstraintState::new(&cfg, &[1.0, 2.0]).is_none());
    }

    #[test]
    fn budgets_start_symmetric_and_shrink() {
        let cfg = AxeConfig::monolithic(16, 8);
        let w = vec![0.0; 32];
        let mut st = ConstraintState::new(&cfg, &w).unwrap();
        let (a0, b0) = st.remaining(0);
        assert!((a0 + b0).abs() < 1e-12, "symmetric start");
        st.commit(0, 5);
        let (_, b1) = st.remaining(0);
        assert!((b0 - b1 - 5.0).abs() < 1e-12);
        st.commit(1, -3);
        let (a2, _) = st.remaining(0);
        assert!((a0 - a2 + 3.0).abs() < 1e-12);
    }

    #[test]
    fn process_clips_into_remaining_budget() {
        let cfg = AxeConfig {
            target: AccumTarget::Monolithic { p_bits: 10 },
            soft: false,
            rounding: Rounding::Nearest,
            act_bits: 4,
        };
        // B = (2^9 - 1)/(2^4 - 1) - 0.5 = 511/15 - 0.5 ≈ 33.57
        let mut st = ConstraintState::new(&cfg, &[0.0; 8]).unwrap();
        let v = st.process(0, 1000.0);
        assert!(v <= 33.6 && v > 33.0);
        st.commit(0, 33);
        let v2 = st.process(1, 1000.0);
        assert!(v2 <= 0.58, "budget nearly exhausted: {v2}");
        let v3 = st.process(1, -1000.0);
        assert!(v3 < -33.0, "negative side untouched");
    }

    #[test]
    fn multistage_tiles_have_independent_budgets() {
        let cfg = AxeConfig::multistage(12, 4, 8);
        let w = vec![0.0; 8];
        let mut st = ConstraintState::new(&cfg, &w).unwrap();
        let (_, b_t0) = st.remaining(0);
        st.commit(0, 3);
        let (_, b_t0_after) = st.remaining(3); // same tile (0..4)
        let (_, b_t1) = st.remaining(4); // next tile
        assert!((b_t0 - b_t0_after - 3.0).abs() < 1e-12);
        assert!((b_t1 - b_t0).abs() < 1e-12, "tile 1 untouched");
    }

    #[test]
    fn soft_lambda_zero_when_inside_budget() {
        let cfg = AxeConfig::monolithic(24, 8); // huge budget
        let w = vec![0.5; 16];
        let st = ConstraintState::new(&cfg, &w).unwrap();
        assert_eq!(st.lambda(0), 0.0);
    }

    #[test]
    fn soft_lambda_positive_when_over_budget() {
        let cfg = AxeConfig::monolithic(8, 8); // tiny budget
        let w = vec![10.0; 64];
        let st = ConstraintState::new(&cfg, &w).unwrap();
        assert!(st.lambda(0) > 0.0);
    }

    /// THE core invariant: any greedy sequence of codes admitted by
    /// ConstraintState is safe for the target accumulator, for any order
    /// of visitation and any adversarial pre-quantization values.
    #[test]
    fn prop_committed_codes_always_safe() {
        quick(
            "axe_guarantee",
            |rng: &mut Rng| {
                let k = rng.int_in(4, 96) as usize;
                let n = rng.int_in(2, 8) as u32;
                let p = rng.int_in(8, 18) as u32;
                let tiled = rng.chance(0.5);
                let tile = if tiled { rng.int_in(2, 32) as usize } else { k };
                let w: Vec<f64> = (0..k).map(|_| rng.normal() * 20.0).collect();
                let order = rng.sample_indices(k, k);
                let seed = rng.next_u64();
                (k, n, p, tile, tiled, w, order, seed)
            },
            |(k, n, p, tile, tiled, w, order, seed)| {
                let target = if *tiled {
                    AccumTarget::MultiStage { p_inner: *p, tile: *tile }
                } else {
                    AccumTarget::Monolithic { p_bits: *p }
                };
                let cfg = AxeConfig { target, soft: true, rounding: Rounding::Nearest, act_bits: *n };
                let mut st = ConstraintState::new(&cfg, w).unwrap();
                let mut rng = Rng::new(*seed);
                let mut q = vec![0i64; *k];
                // visit in arbitrary order with adversarial values
                for &i in order {
                    let v_raw = rng.normal() * 50.0;
                    let v = st.process(i, v_raw);
                    // round-to-nearest may add up to 0.5 — exactly the slack Eq.21 reserves
                    let code = Rounding::Nearest.round(v) as i64;
                    st.commit(i, code);
                    q[i] = code;
                }
                let nu = (1i64 << n) - 1;
                let (pt, tl) = cfg.target.tile_plan(*k).unwrap();
                for (ti, chunk) in q.chunks(tl).enumerate() {
                    if !is_safe(chunk, 0, nu, pt) {
                        return Err(format!("tile {ti} overflows P={pt}"));
                    }
                }
                if let Some(po) = cfg.target.outer_bits(*k) {
                    if !is_safe(&q, 0, nu, po) {
                        return Err(format!("outer accumulator overflows P_O={po}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn describe_strings() {
        assert_eq!(AccumTarget::None.describe(), "base");
        assert_eq!(AccumTarget::Monolithic { p_bits: 16 }.describe(), "P16");
        assert_eq!(AccumTarget::MultiStage { p_inner: 16, tile: 64 }.describe(), "64x16b");
    }
}
