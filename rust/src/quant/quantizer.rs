//! Uniform affine quantizers (paper Eq. 1, Eq. 27).
//!
//! Weights: symmetric per-channel (z = 0), s_c = max|w_c| / (2^{M−1}−1).
//! Activations: asymmetric per-tensor, zero-point calibrated to a
//! percentile window of the calibration data, codes unsigned in
//! [0, 2^N−1] — the μ=0, ν=2^N−1 setting §3.2 derives the strict
//! constraint for.

use super::alphabet::Alphabet;

/// Rounding functions. `max_delta` is the worst-case magnitude increase
/// from rounding (paper Eq. 21): 0.5 for round-to-nearest, 0 for
/// round-to-zero.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// Round to nearest, ties away from zero (PyTorch `round`-like).
    Nearest,
    /// Round toward zero (truncation) — EP-init's requirement.
    Zero,
}

impl Rounding {
    #[inline]
    pub fn round(&self, x: f64) -> f64 {
        match self {
            Rounding::Nearest => x.round(),
            Rounding::Zero => x.trunc(),
        }
    }

    /// Worst-case |round(x)| − |x| (Eq. 21's max(Δ)).
    #[inline]
    pub fn max_delta(&self) -> f64 {
        match self {
            Rounding::Nearest => 0.5,
            Rounding::Zero => 0.0,
        }
    }
}

/// Per-channel symmetric weight quantizer.
#[derive(Clone, Debug)]
pub struct WeightQuantizer {
    pub alphabet: Alphabet,
    /// One scale per output channel; strictly positive.
    pub scales: Vec<f64>,
    pub rounding: Rounding,
}

impl WeightQuantizer {
    /// Fit per-channel scales from a weight matrix given as K×C columns
    /// (channel c = column c), per Eq. 27.
    pub fn fit_columns(w: &crate::linalg::Mat, bits: u32, rounding: Rounding) -> WeightQuantizer {
        let alphabet = Alphabet::signed(bits);
        let qmax = alphabet.max_val() as f64;
        let c = w.cols();
        let mut scales = vec![0.0f64; c];
        for i in 0..w.rows() {
            let row = w.row(i);
            for (j, &v) in row.iter().enumerate() {
                scales[j] = scales[j].max(v.abs());
            }
        }
        for s in &mut scales {
            *s = (*s / qmax).max(1e-12);
        }
        WeightQuantizer { alphabet, scales, rounding }
    }

    /// Quantize a scaled value (w/s already applied) to an integer code.
    #[inline]
    pub fn to_code_scaled(&self, v_scaled: f64) -> i64 {
        self.alphabet.clamp(self.rounding.round(v_scaled) as i64)
    }

    /// Quantize a real value for channel `c`.
    #[inline]
    pub fn to_code(&self, v: f64, c: usize) -> i64 {
        self.to_code_scaled(v / self.scales[c])
    }

    /// Dequantize a code for channel `c`.
    #[inline]
    pub fn from_code(&self, q: i64, c: usize) -> f64 {
        q as f64 * self.scales[c]
    }
}

/// Per-tensor asymmetric activation quantizer. Codes are unsigned in
/// [0, 2^N−1]; real value = s·(code − z).
#[derive(Clone, Copy, Debug)]
pub struct ActQuantizer {
    pub alphabet: Alphabet,
    pub scale: f64,
    pub zero_point: i64,
}

impl ActQuantizer {
    /// Calibrate from sample values using a two-sided percentile window
    /// (the paper tunes z to the lowest 99th percentile; we clip both
    /// tails at `pct`, e.g. 0.999).
    pub fn calibrate(samples: &[f64], bits: u32, pct: f64) -> ActQuantizer {
        assert!(!samples.is_empty(), "cannot calibrate on empty samples");
        let alphabet = Alphabet::unsigned(bits);
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let hi_idx = (((n as f64) * pct).ceil() as usize).clamp(1, n) - 1;
        let lo_idx = (((n as f64) * (1.0 - pct)).floor() as usize).min(n - 1);
        let lo = sorted[lo_idx].min(0.0);
        let mut hi = sorted[hi_idx].max(0.0);
        if hi - lo < 1e-12 {
            hi = lo + 1e-6;
        }
        let levels = (alphabet.levels() - 1) as f64;
        let scale = (hi - lo) / levels;
        let zero_point = (-lo / scale).round() as i64;
        let zero_point = zero_point.clamp(alphabet.min_val(), alphabet.max_val());
        ActQuantizer { alphabet, scale, zero_point }
    }

    /// Identity-ish quantizer for tests: scale 1, zp 0.
    pub fn unit(bits: u32) -> ActQuantizer {
        ActQuantizer { alphabet: Alphabet::unsigned(bits), scale: 1.0, zero_point: 0 }
    }

    #[inline]
    pub fn to_code(&self, x: f64) -> i64 {
        self.alphabet.clamp((x / self.scale).round() as i64 + self.zero_point)
    }

    #[inline]
    pub fn from_code(&self, code: i64) -> f64 {
        (code - self.zero_point) as f64 * self.scale
    }

    /// Quantize-dequantize (fake-quant) a value.
    #[inline]
    pub fn fake(&self, x: f64) -> f64 {
        self.from_code(self.to_code(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::rng::Rng;

    #[test]
    fn rounding_functions() {
        assert_eq!(Rounding::Nearest.round(1.5), 2.0);
        assert_eq!(Rounding::Nearest.round(-1.5), -2.0);
        assert_eq!(Rounding::Zero.round(1.9), 1.0);
        assert_eq!(Rounding::Zero.round(-1.9), -1.0);
        assert_eq!(Rounding::Nearest.max_delta(), 0.5);
        assert_eq!(Rounding::Zero.max_delta(), 0.0);
    }

    #[test]
    fn rtz_never_increases_magnitude() {
        let mut rng = Rng::new(31);
        for _ in 0..1000 {
            let x = rng.normal() * 10.0;
            assert!(Rounding::Zero.round(x).abs() <= x.abs());
        }
    }

    #[test]
    fn weight_quantizer_scales_cover_max() {
        let mut rng = Rng::new(32);
        let w = Mat::random_normal(16, 4, &mut rng, 2.0);
        let q = WeightQuantizer::fit_columns(&w, 4, Rounding::Nearest);
        assert_eq!(q.scales.len(), 4);
        for c in 0..4 {
            let maxabs = (0..16).map(|i| w.get(i, c).abs()).fold(0.0f64, f64::max);
            // code of the max element must be exactly qmax
            let code = q.to_code(maxabs, c);
            assert_eq!(code, q.alphabet.max_val());
        }
    }

    #[test]
    fn weight_roundtrip_error_bounded() {
        let mut rng = Rng::new(33);
        let w = Mat::random_normal(64, 8, &mut rng, 1.0);
        let q = WeightQuantizer::fit_columns(&w, 8, Rounding::Nearest);
        for c in 0..8 {
            for i in 0..64 {
                let v = w.get(i, c);
                let deq = q.from_code(q.to_code(v, c), c);
                assert!((v - deq).abs() <= 0.5 * q.scales[c] + 1e-12);
            }
        }
    }

    #[test]
    fn act_quantizer_codes_unsigned() {
        let mut rng = Rng::new(34);
        let samples: Vec<f64> = (0..5000).map(|_| rng.normal()).collect();
        let q = ActQuantizer::calibrate(&samples, 8, 0.999);
        for &x in samples.iter().take(500) {
            let code = q.to_code(x);
            assert!((0..=255).contains(&code));
        }
        // zero must be exactly representable (paper §2.1)
        assert_eq!(q.to_code(0.0), q.zero_point);
        assert!((q.from_code(q.zero_point)).abs() < 1e-12);
    }

    #[test]
    fn act_quantizer_relu_like_inputs() {
        // non-negative inputs -> zero_point ~ 0
        let samples: Vec<f64> = (0..1000).map(|i| (i as f64) / 100.0).collect();
        let q = ActQuantizer::calibrate(&samples, 8, 1.0);
        assert_eq!(q.zero_point, 0);
        let err = (q.fake(5.0) - 5.0).abs();
        assert!(err <= q.scale);
    }

    #[test]
    fn act_quantizer_percentile_clips_outliers() {
        let mut samples = vec![0.0; 999];
        for (i, s) in samples.iter_mut().enumerate() {
            *s = (i as f64) / 999.0;
        }
        samples.push(1000.0); // outlier
        let q = ActQuantizer::calibrate(&samples, 8, 0.99);
        // the outlier should be clipped, so scale covers ~[0,1], not [0,1000]
        assert!(q.scale < 0.05, "scale={}", q.scale);
    }

    #[test]
    fn constant_input_does_not_divide_by_zero() {
        let samples = vec![3.0; 100];
        let q = ActQuantizer::calibrate(&samples, 4, 1.0);
        assert!(q.scale > 0.0);
        let _ = q.to_code(3.0);
    }
}
