//! GPFQ — greedy path-following quantization (Lybrand & Saab, 2021) with
//! the paper's accumulator-aware extension (Algorithm 1) and the
//! memory-efficient square-matrix reformulation (Theorem B.1).
//!
//! Standard form (Eq. 11-12), per output channel:
//!   v_i = (⟨X̃_i, u_{i−1}⟩ + w_i ⟨X̃_i, X_i⟩) / ‖X̃_i‖²
//!   q_i = Q ∘ Ψ_{a,b} ∘ Π_λ(v_i / s)
//!   u_i = u_{i−1} + w_i X_i − (s·q_i) X̃_i
//!
//! Memory-efficient form: with H = (X̃X̃ᵀ)^{1/2} and G = XX̃ᵀ,
//!   GPFQ(W, X, X̃) = GPFQ(W, GH⁻¹, H)   — O(K²) memory instead of O(KD).

use super::axe::AxeConfig;
use super::quantizer::WeightQuantizer;
use super::result::QuantResult;
use crate::linalg::{dot, sqrtm_psd, Mat};

/// Parameters for a GPFQ run.
#[derive(Clone, Copy, Debug)]
pub struct GpfqParams {
    /// Weight bit width M.
    pub weight_bits: u32,
    /// Accumulator-aware extension config (target None = base GPFQ).
    pub axe: AxeConfig,
    /// Quantize inputs in descending ‖X̃_i‖² order (act-order heuristic,
    /// App. C.1).
    pub act_order: bool,
}

impl GpfqParams {
    pub fn base(weight_bits: u32, act_bits: u32) -> GpfqParams {
        GpfqParams {
            weight_bits,
            axe: AxeConfig::unconstrained(super::quantizer::Rounding::Nearest, act_bits),
            act_order: true,
        }
    }
}

/// Quantize one layer with GPFQ from full data matrices.
///
/// * `w`  — K×C float weights (input index × output channel).
/// * `x`  — K×D float-model inputs (row i = samples of input neuron i).
/// * `xt` — K×D inputs under the already-quantized prefix network
///          (dequantized real values).
pub fn gpfq_quantize(w: &Mat, x: &Mat, xt: &Mat, params: &GpfqParams) -> QuantResult {
    let (k, c) = (w.rows(), w.cols());
    assert_eq!(x.rows(), k, "x rows must equal K");
    assert_eq!(xt.rows(), k, "xt rows must equal K");
    assert_eq!(x.cols(), xt.cols(), "x/xt sample count mismatch");
    let d = x.cols();

    let wq = WeightQuantizer::fit_columns(w, params.weight_bits, params.axe.rounding);
    let mut result = QuantResult::new(k, c, params.weight_bits, wq.scales.clone());
    if k == 0 || c == 0 {
        return result;
    }

    // Shared per-index precomputation.
    let norm_sq: Vec<f64> = (0..k).map(|i| dot(xt.row(i), xt.row(i))).collect();
    let cross: Vec<f64> = (0..k).map(|i| dot(xt.row(i), x.row(i))).collect();
    let order = visit_order(&norm_sq, params.act_order);

    // Channel-parallel main loop.
    let nthreads = crate::linalg::num_threads().min(c).max(1);
    let chunk = c.div_ceil(nthreads);
    let mut per_thread: Vec<Vec<(usize, Vec<i64>)>> = Vec::with_capacity(nthreads);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..nthreads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(c);
            if lo >= hi {
                continue;
            }
            let wq_ref = &wq;
            let norm_sq = &norm_sq;
            let cross = &cross;
            let order = &order;
            handles.push(scope.spawn(move || {
                let mut out = Vec::with_capacity(hi - lo);
                let mut u = vec![0.0f64; d];
                for ch in lo..hi {
                    u.iter_mut().for_each(|v| *v = 0.0);
                    let codes =
                        gpfq_channel(w, x, xt, ch, wq_ref, norm_sq, cross, order, params, &mut u);
                    out.push((ch, codes));
                }
                out
            }));
        }
        for h in handles {
            per_thread.push(h.join().expect("gpfq worker panicked"));
        }
    });
    for chunk in per_thread {
        for (ch, codes) in chunk {
            for (i, q) in codes.into_iter().enumerate() {
                result.set_code(i, ch, q);
            }
        }
    }
    result
}

/// One channel of the GPFQ iteration. `u` is a scratch buffer of length D.
#[allow(clippy::too_many_arguments)]
fn gpfq_channel(
    w: &Mat,
    x: &Mat,
    xt: &Mat,
    ch: usize,
    wq: &WeightQuantizer,
    norm_sq: &[f64],
    cross: &[f64],
    order: &[usize],
    params: &GpfqParams,
    u: &mut [f64],
) -> Vec<i64> {
    let k = w.rows();
    let s = wq.scales[ch];
    let w_scaled: Vec<f64> = (0..k).map(|i| w.get(i, ch) / s).collect();
    let mut constraint = super::axe::ConstraintState::new(&params.axe, &w_scaled);
    let mut codes = vec![0i64; k];
    const EPS: f64 = 1e-30;

    for &i in order {
        let w_ic = w.get(i, ch);
        let xt_i = xt.row(i);
        let x_i = x.row(i);
        let q = if norm_sq[i] <= EPS {
            // Dead direction: any code contributes nothing to the output;
            // pick 0 and carry the uncorrectable error forward.
            0
        } else {
            let v = (dot(xt_i, u) + w_ic * cross[i]) / norm_sq[i];
            let mut vs = v / s;
            if let Some(st) = constraint.as_ref() {
                vs = st.process(i, vs);
            }
            wq.to_code_scaled(vs)
        };
        if let Some(st) = constraint.as_mut() {
            st.commit(i, q);
        }
        codes[i] = q;
        // u += w_i X_i − (s q) X̃_i
        let deq = q as f64 * s;
        if q != 0 || w_ic != 0.0 {
            for j in 0..u.len() {
                u[j] += w_ic * x_i[j] - deq * xt_i[j];
            }
        }
    }
    codes
}

/// Memory-efficient GPFQ (Theorem B.1): run GPFQ on K×K surrogates built
/// from the Gram matrices.
///
/// * `g` — G = X X̃ᵀ (K×K), accumulated streamingly by the caller.
/// * `a` — A = X̃ X̃ᵀ (K×K), same.
/// * `damp` — relative diagonal damping (fraction of mean diagonal) that
///   keeps A invertible; mirrors OPTQ's η.
pub fn gpfq_quantize_grams(
    w: &Mat,
    g: &Mat,
    a: &Mat,
    params: &GpfqParams,
    damp: f64,
) -> anyhow::Result<QuantResult> {
    let k = w.rows();
    assert_eq!(g.rows(), k);
    assert_eq!(g.cols(), k);
    assert_eq!(a.rows(), k);
    assert_eq!(a.cols(), k);
    let mut a_damped = a.clone();
    let mean_diag = a.diag().iter().sum::<f64>() / k.max(1) as f64;
    a_damped.add_diag(damp * mean_diag.max(1e-12));
    let rt = sqrtm_psd(&a_damped, 1e-11, 100)
        .map_err(|e| anyhow::anyhow!("sqrtm failed in memory-efficient GPFQ: {e}"))?;
    // X_eff = G H⁻¹, X̃_eff = H.
    let x_eff = g.matmul(&rt.inv_sqrt);
    Ok(gpfq_quantize(w, &x_eff, &rt.sqrt, params))
}

/// Visitation order: descending ‖X̃_i‖² when act_order, else natural.
fn visit_order(norm_sq: &[f64], act_order: bool) -> Vec<usize> {
    let mut order: Vec<usize> = (0..norm_sq.len()).collect();
    if act_order {
        order.sort_by(|&a, &b| norm_sq[b].partial_cmp(&norm_sq[a]).unwrap());
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::axe::AccumTarget;
    use crate::quant::bounds::is_safe;
    use crate::quant::quantizer::Rounding;
    use crate::util::rng::Rng;

    fn recon_error(w: &Mat, x: &Mat, q: &Mat, xt: &Mat) -> f64 {
        // ‖Xᵀw − X̃ᵀq‖ summed over channels
        let wx = x.transpose().matmul(w);
        let qx = xt.transpose().matmul(q);
        crate::linalg::frob_diff(&wx, &qx)
    }

    fn random_problem(k: usize, c: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        let w = Mat::random_normal(k, c, &mut rng, 0.3);
        let x = Mat::random_normal(k, d, &mut rng, 1.0);
        // xt = x + small perturbation (models quantized-prefix activations)
        let mut xt = x.clone();
        for v in xt.data_mut() {
            *v += rng.normal() * 0.01;
        }
        (w, x, xt)
    }

    #[test]
    fn orthogonal_data_reduces_to_rounding() {
        // X = X̃ = I ⇒ error feedback is orthogonal to future steps ⇒
        // GPFQ must produce plain RTN codes.
        let mut rng = Rng::new(40);
        let k = 16;
        let w = Mat::random_normal(k, 3, &mut rng, 0.5);
        let eye = Mat::eye(k);
        let params = GpfqParams { act_order: false, ..GpfqParams::base(4, 8) };
        let r = gpfq_quantize(&w, &eye, &eye, &params);
        let wq = WeightQuantizer::fit_columns(&w, 4, Rounding::Nearest);
        for ch in 0..3 {
            for i in 0..k {
                assert_eq!(r.code(i, ch), wq.to_code(w.get(i, ch), ch), "i={i} ch={ch}");
            }
        }
    }

    #[test]
    fn beats_naive_rounding_on_correlated_data() {
        let (w, x, xt) = random_problem(48, 8, 256, 41);
        let params = GpfqParams::base(4, 8);
        let r = gpfq_quantize(&w, &x, &xt, &params);
        // naive RTN baseline
        let wq = WeightQuantizer::fit_columns(&w, 4, Rounding::Nearest);
        let naive = Mat::from_fn(48, 8, |i, ch| wq.from_code(wq.to_code(w.get(i, ch), ch), ch));
        let e_gpfq = recon_error(&w, &x, &r.dequant(), &xt);
        let e_naive = recon_error(&w, &x, &naive, &xt);
        assert!(
            e_gpfq < e_naive,
            "GPFQ ({e_gpfq:.4}) must beat naive rounding ({e_naive:.4})"
        );
    }

    #[test]
    fn axe_codes_respect_accumulator() {
        let (w, x, xt) = random_problem(64, 6, 128, 42);
        let mut params = GpfqParams::base(4, 8);
        params.axe = AxeConfig::monolithic(14, 8);
        let r = gpfq_quantize(&w, &x, &xt, &params);
        for ch in 0..6 {
            let q = r.channel_codes(ch);
            assert!(is_safe(&q, 0, 255, 14), "channel {ch} violates P=14");
        }
    }

    #[test]
    fn axe_multistage_codes_respect_tiles() {
        let (w, x, xt) = random_problem(96, 4, 128, 43);
        let mut params = GpfqParams::base(4, 8);
        params.axe = AxeConfig::multistage(12, 32, 8);
        let r = gpfq_quantize(&w, &x, &xt, &params);
        for ch in 0..4 {
            let q = r.channel_codes(ch);
            assert!(
                crate::quant::bounds::is_safe_multistage(&q, 0, 255, 12, 32),
                "channel {ch} violates 32x12b"
            );
        }
    }

    #[test]
    fn huge_accumulator_equals_base() {
        let (w, x, xt) = random_problem(32, 5, 96, 44);
        let base = GpfqParams { act_order: true, ..GpfqParams::base(4, 8) };
        let mut constrained = base;
        constrained.axe = AxeConfig {
            target: AccumTarget::Monolithic { p_bits: 32 },
            soft: true,
            rounding: Rounding::Nearest,
            act_bits: 8,
        };
        let r1 = gpfq_quantize(&w, &x, &xt, &base);
        let r2 = gpfq_quantize(&w, &x, &xt, &constrained);
        assert_eq!(r1.codes, r2.codes, "32-bit budget must be a no-op");
    }

    #[test]
    fn memory_efficient_matches_standard() {
        // Theorem B.1: GPFQ(W, X, X̃) == GPFQ(W, GH⁻¹, H) — codes must
        // match exactly (up to fp tolerance pushed through the argmin,
        // so compare codes with D > K for well-conditioned grams).
        let (w, x, xt) = random_problem(24, 6, 200, 45);
        let params = GpfqParams::base(4, 8);
        let r_std = gpfq_quantize(&w, &x, &xt, &params);
        let g = x.matmul_bt(&xt);
        let a = xt.gram();
        let r_mem = gpfq_quantize_grams(&w, &g, &a, &params, 0.0).unwrap();
        let diff: usize = r_std
            .codes
            .iter()
            .zip(r_mem.codes.iter())
            .filter(|(a, b)| a != b)
            .count();
        assert!(
            diff <= r_std.codes.len() / 50,
            "mem-efficient GPFQ diverged on {diff}/{} codes",
            r_std.codes.len()
        );
        // and the reconstruction errors must agree tightly
        let e1 = recon_error(&w, &x, &r_std.dequant(), &xt);
        let e2 = recon_error(&w, &x, &r_mem.dequant(), &xt);
        assert!((e1 - e2).abs() / e1.max(1e-9) < 0.05, "e_std={e1} e_mem={e2}");
    }

    #[test]
    fn dead_inputs_get_zero_codes() {
        let mut rng = Rng::new(46);
        let w = Mat::random_normal(8, 2, &mut rng, 1.0);
        let mut x = Mat::random_normal(8, 32, &mut rng, 1.0);
        let mut xt = x.clone();
        // kill input 3
        for j in 0..32 {
            x.set(3, j, 0.0);
            xt.set(3, j, 0.0);
        }
        let params = GpfqParams::base(4, 8);
        let r = gpfq_quantize(&w, &x, &xt, &params);
        assert_eq!(r.code(3, 0), 0);
        assert_eq!(r.code(3, 1), 0);
    }

    #[test]
    fn empty_layer_is_ok() {
        let w = Mat::zeros(0, 0);
        let x = Mat::zeros(0, 4);
        let params = GpfqParams::base(4, 8);
        let r = gpfq_quantize(&w, &x, &x, &params);
        assert_eq!(r.codes.len(), 0);
    }
}
