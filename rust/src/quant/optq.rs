//! OPTQ (a.k.a. GPTQ; Frantar et al., 2022) with the paper's
//! accumulator-aware extension (Algorithm 2).
//!
//! The layer Hessian proxy is H = 2 X̃X̃ᵀ + ηI with η = 1% of the mean
//! diagonal. The error-propagation factor is the upper-triangular
//! Cholesky factor U of H⁻¹ (H⁻¹ = UᵀU):
//!
//!   V_i = Ψ_{a,b} ∘ Π_λ (W_i / s)            (accumulator-aware step)
//!   Q_i = Q(V_i)
//!   E   = (W_i − s·Q_i) / U_{i,i}
//!   W_{j>i} ← W_{j>i} − E · U_{i,j}
//!
//! Act-order (descending Hessian diagonal) is applied as a permutation;
//! AXE tile budgets are tracked on *original* input positions so the
//! physical datapath tiling is respected regardless of visit order.

use super::axe::AxeConfig;
use super::quantizer::WeightQuantizer;
use super::result::QuantResult;
use crate::linalg::{cholesky_lower, spd_inverse, Mat};

/// Parameters for an OPTQ run.
#[derive(Clone, Copy, Debug)]
pub struct OptqParams {
    /// Weight bit width M.
    pub weight_bits: u32,
    /// Accumulator-aware extension config (target None = base OPTQ).
    pub axe: AxeConfig,
    /// Quantize in descending Hessian-diagonal order (App. C.1).
    pub act_order: bool,
    /// Relative dampening η as a fraction of the mean Hessian diagonal.
    pub damp: f64,
}

impl OptqParams {
    pub fn base(weight_bits: u32, act_bits: u32) -> OptqParams {
        OptqParams {
            weight_bits,
            axe: AxeConfig::unconstrained(super::quantizer::Rounding::Nearest, act_bits),
            act_order: true,
            damp: 0.01,
        }
    }
}

/// Quantize one layer with OPTQ.
///
/// * `w` — K×C float weights (input index × output channel).
/// * `gram` — X̃X̃ᵀ (K×K) from calibration data under the quantized
///   prefix network.
pub fn optq_quantize(w: &Mat, gram: &Mat, params: &OptqParams) -> anyhow::Result<QuantResult> {
    let (k, c) = (w.rows(), w.cols());
    assert_eq!(gram.rows(), k, "gram must be K×K");
    assert_eq!(gram.cols(), k, "gram must be K×K");

    let wq = WeightQuantizer::fit_columns(w, params.weight_bits, params.axe.rounding);
    let mut result = QuantResult::new(k, c, params.weight_bits, wq.scales.clone());
    if k == 0 || c == 0 {
        return Ok(result);
    }

    // H = 2·gram + ηI
    let mut h = gram.clone();
    h.scale(2.0);
    let mean_diag = h.diag().iter().sum::<f64>() / k as f64;
    h.add_diag((params.damp * mean_diag).max(1e-10));

    // act-order permutation by descending diagonal
    let perm = if params.act_order {
        let diag = h.diag();
        let mut idx: Vec<usize> = (0..k).collect();
        idx.sort_by(|&a, &b| diag[b].partial_cmp(&diag[a]).unwrap());
        idx
    } else {
        (0..k).collect()
    };
    let h_p = permute_sym(&h, &perm);

    // U upper-triangular with H⁻¹ = UᵀU  (U = Lᵀ, H⁻¹ = L Lᵀ)
    let hinv = spd_inverse(&h_p).map_err(|e| anyhow::anyhow!("OPTQ hessian inversion: {e}"))?;
    let l = cholesky_lower(&hinv).map_err(|e| anyhow::anyhow!("OPTQ cholesky: {e}"))?;
    let u = l.transpose();

    // Channel-parallel loop: each worker owns a slice of channels with a
    // private working copy of the (permuted) weights.
    let nthreads = crate::linalg::num_threads().min(c).max(1);
    let chunk = c.div_ceil(nthreads);
    let mut per_thread: Vec<Vec<(usize, Vec<i64>)>> = Vec::with_capacity(nthreads);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..nthreads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(c);
            if lo >= hi {
                continue;
            }
            let wq_ref = &wq;
            let u_ref = &u;
            let perm_ref = &perm;
            handles.push(scope.spawn(move || {
                let mut out = Vec::with_capacity(hi - lo);
                for ch in lo..hi {
                    out.push((ch, optq_channel(w, ch, wq_ref, u_ref, perm_ref, params)));
                }
                out
            }));
        }
        for h in handles {
            per_thread.push(h.join().expect("optq worker panicked"));
        }
    });
    for chunk in per_thread {
        for (ch, codes) in chunk {
            for (i, q) in codes.into_iter().enumerate() {
                result.set_code(i, ch, q);
            }
        }
    }
    Ok(result)
}

/// One channel of OPTQ over the permuted index space. Returns codes in
/// the ORIGINAL index space.
fn optq_channel(
    w: &Mat,
    ch: usize,
    wq: &WeightQuantizer,
    u: &Mat,
    perm: &[usize],
    params: &OptqParams,
) -> Vec<i64> {
    let k = w.rows();
    let s = wq.scales[ch];
    // working copy in permuted order
    let mut wv: Vec<f64> = perm.iter().map(|&i| w.get(i, ch)).collect();
    let w_scaled: Vec<f64> = (0..k).map(|i| w.get(i, ch) / s).collect();
    let mut constraint = super::axe::ConstraintState::new(&params.axe, &w_scaled);
    let mut codes = vec![0i64; k];

    for ip in 0..k {
        let orig = perm[ip];
        let mut vs = wv[ip] / s;
        if let Some(st) = constraint.as_ref() {
            vs = st.process(orig, vs);
        }
        let q = wq.to_code_scaled(vs);
        if let Some(st) = constraint.as_mut() {
            st.commit(orig, q);
        }
        codes[orig] = q;
        let deq = q as f64 * s;
        let uii = u.get(ip, ip);
        if uii.abs() > 1e-30 {
            let e = (wv[ip] - deq) / uii;
            let urow = u.row(ip);
            for jp in (ip + 1)..k {
                wv[jp] -= e * urow[jp];
            }
        }
    }
    codes
}

/// Symmetric permutation of a square matrix: out[a][b] = m[p[a]][p[b]].
fn permute_sym(m: &Mat, perm: &[usize]) -> Mat {
    let k = m.rows();
    Mat::from_fn(k, k, |a, b| m.get(perm[a], perm[b]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::axe::AccumTarget;
    use crate::quant::bounds::{is_safe, is_safe_multistage};
    use crate::quant::quantizer::Rounding;
    use crate::util::rng::Rng;

    fn random_problem(k: usize, c: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        let w = Mat::random_normal(k, c, &mut rng, 0.3);
        let xt = Mat::random_normal(k, d, &mut rng, 1.0);
        let gram = xt.gram();
        (w, xt, gram)
    }

    fn recon_error(w: &Mat, q: &Mat, xt: &Mat) -> f64 {
        let wx = xt.transpose().matmul(w);
        let qx = xt.transpose().matmul(q);
        crate::linalg::frob_diff(&wx, &qx)
    }

    #[test]
    fn beats_naive_rounding() {
        let (w, xt, gram) = random_problem(48, 8, 256, 50);
        let params = OptqParams::base(4, 8);
        let r = optq_quantize(&w, &gram, &params).unwrap();
        let wq = WeightQuantizer::fit_columns(&w, 4, Rounding::Nearest);
        let naive = Mat::from_fn(48, 8, |i, ch| wq.from_code(wq.to_code(w.get(i, ch), ch), ch));
        let e_optq = recon_error(&w, &r.dequant(), &xt);
        let e_naive = recon_error(&w, &naive, &xt);
        assert!(e_optq < e_naive, "OPTQ ({e_optq:.4}) must beat naive ({e_naive:.4})");
    }

    #[test]
    fn diagonal_hessian_reduces_to_rounding() {
        // With an (isotropic) diagonal Hessian and no act-order there is
        // no cross-coordinate error to propagate: codes == RTN codes.
        let mut rng = Rng::new(51);
        let k = 16;
        let w = Mat::random_normal(k, 3, &mut rng, 0.5);
        let gram = Mat::eye(k);
        let params = OptqParams { act_order: false, ..OptqParams::base(4, 8) };
        let r = optq_quantize(&w, &gram, &params).unwrap();
        let wq = WeightQuantizer::fit_columns(&w, 4, Rounding::Nearest);
        for ch in 0..3 {
            for i in 0..k {
                assert_eq!(r.code(i, ch), wq.to_code(w.get(i, ch), ch));
            }
        }
    }

    #[test]
    fn axe_monolithic_safe() {
        let (w, _xt, gram) = random_problem(64, 6, 128, 52);
        let mut params = OptqParams::base(4, 8);
        params.axe = AxeConfig::monolithic(14, 8);
        let r = optq_quantize(&w, &gram, &params).unwrap();
        for ch in 0..6 {
            assert!(is_safe(&r.channel_codes(ch), 0, 255, 14), "ch={ch}");
        }
    }

    #[test]
    fn axe_multistage_safe_with_act_order() {
        // act-order permutation must NOT break physical tile budgets
        let (w, _xt, gram) = random_problem(96, 4, 160, 53);
        let mut params = OptqParams::base(4, 8);
        params.axe = AxeConfig::multistage(12, 32, 8);
        params.act_order = true;
        let r = optq_quantize(&w, &gram, &params).unwrap();
        for ch in 0..4 {
            assert!(is_safe_multistage(&r.channel_codes(ch), 0, 255, 12, 32), "ch={ch}");
        }
    }

    #[test]
    fn huge_accumulator_equals_base() {
        let (w, _xt, gram) = random_problem(32, 5, 96, 54);
        let base = OptqParams::base(4, 8);
        let mut constrained = base;
        constrained.axe = AxeConfig {
            target: AccumTarget::Monolithic { p_bits: 32 },
            soft: true,
            rounding: Rounding::Nearest,
            act_bits: 8,
        };
        let r1 = optq_quantize(&w, &gram, &base).unwrap();
        let r2 = optq_quantize(&w, &gram, &constrained).unwrap();
        assert_eq!(r1.codes, r2.codes);
    }

    #[test]
    fn act_order_helps_or_matches() {
        // Not a theorem, but on act-heavy data it should rarely hurt; we
        // assert it stays within 20% to catch sign errors in the
        // permutation plumbing.
        let (w, xt, gram) = random_problem(64, 8, 256, 55);
        let mut p_on = OptqParams::base(4, 8);
        p_on.act_order = true;
        let mut p_off = p_on;
        p_off.act_order = false;
        let e_on = recon_error(&w, &optq_quantize(&w, &gram, &p_on).unwrap().dequant(), &xt);
        let e_off = recon_error(&w, &optq_quantize(&w, &gram, &p_off).unwrap().dequant(), &xt);
        assert!(e_on <= e_off * 1.2, "act-order exploded: {e_on} vs {e_off}");
    }

    #[test]
    fn permute_sym_roundtrip() {
        let mut rng = Rng::new(56);
        let m = {
            let x = Mat::random_normal(6, 10, &mut rng, 1.0);
            x.gram()
        };
        let perm = vec![3, 1, 5, 0, 2, 4];
        let p = permute_sym(&m, &perm);
        for a in 0..6 {
            for b in 0..6 {
                assert_eq!(p.get(a, b), m.get(perm[a], perm[b]));
            }
        }
        assert!(p.is_symmetric(1e-12));
    }
}
