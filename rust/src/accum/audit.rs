//! Overflow audit: verify the avoidance guarantee bit-exactly.
//!
//! For a channel with integer codes q and unsigned N-bit inputs, the
//! extremal inputs are (Eq. 6): u_i = ν where q_i ≥ 0 else μ, and the
//! mirror image v. The audit evaluates those two adversarial vectors
//! per tile (they dominate every other input), plus randomized fuzzing
//! as a defense-in-depth check on the simulator itself.

use super::simulator::{dot_multistage, AccumSpec};
use crate::quant::bounds::{outer_bits, worst_case_range};
use crate::util::rng::Rng;

/// Outcome of auditing one channel (or a whole layer, aggregated).
#[derive(Clone, Debug, Default)]
pub struct AuditReport {
    /// Dot products audited (2 worst-case per tile + fuzz vectors).
    pub cases: usize,
    /// Cases in which a register left its range.
    pub violations: usize,
    /// Worst observed |accumulator| / register-capacity ratio.
    pub worst_utilization: f64,
}

impl AuditReport {
    pub fn merge(&mut self, other: &AuditReport) {
        self.cases += other.cases;
        self.violations += other.violations;
        self.worst_utilization = self.worst_utilization.max(other.worst_utilization);
    }

    pub fn clean(&self) -> bool {
        self.violations == 0
    }
}

/// Audit one channel's codes against the worst-case inputs for a
/// multi-stage (or monolithic: tile ≥ K) datapath.
pub fn audit_channel(q: &[i64], act_bits: u32, p_inner: u32, tile: usize) -> AuditReport {
    let nu = (1i64 << act_bits) - 1;
    let mu = 0i64;
    let inner_cap = ((1i128 << (p_inner - 1)) - 1) as f64;
    let p_outer = outer_bits(p_inner, q.len(), tile);
    let outer_cap = ((1i128 << (p_outer - 1)) - 1) as f64;

    let mut report = AuditReport::default();
    // Worst case per tile (inner registers).
    for chunk in q.chunks(tile) {
        let (hi, lo) = worst_case_range(chunk, mu, nu);
        report.cases += 2;
        let util = (hi.max(-lo)) as f64 / inner_cap;
        report.worst_utilization = report.worst_utilization.max(util);
        if util > 1.0 {
            report.violations += 1;
        }
    }
    // Worst case for the whole dot product (outer register). The global
    // extremal input simultaneously maximizes every tile, so it is also
    // the outer worst case.
    let (hi, lo) = worst_case_range(q, mu, nu);
    report.cases += 2;
    let util = (hi.max(-lo)) as f64 / outer_cap;
    if util > 1.0 {
        report.violations += 1;
    }
    report
}

/// Randomized fuzz audit through the actual simulator: draws random
/// input vectors and checks the wraparound datapath agrees with exact
/// arithmetic (i.e. no overflow events fired).
pub fn audit_random(
    q: &[i64],
    act_bits: u32,
    p_inner: u32,
    tile: usize,
    fuzz: usize,
    rng: &mut Rng,
) -> AuditReport {
    let nu = (1i64 << act_bits) - 1;
    let p_outer = outer_bits(p_inner, q.len(), tile);
    let inner = AccumSpec::wraparound(p_inner);
    let outer = AccumSpec::wraparound(p_outer);
    let mut report = AuditReport::default();
    let mut x = vec![0i64; q.len()];
    for _ in 0..fuzz {
        for xi in &mut x {
            *xi = rng.int_in(0, nu);
        }
        let out = dot_multistage(&x, q, tile, inner, outer);
        report.cases += 1;
        if out.overflows > 0 {
            report.violations += 1;
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bounds::side_budget;

    fn budget_codes(k: usize, tile: usize, p: u32, n: u32, seed: u64) -> Vec<i64> {
        let b = side_budget(p, n, 0.0);
        let mut rng = Rng::new(seed);
        let mut q = vec![0i64; k];
        let nt = k.div_ceil(tile);
        let (mut pos, mut neg) = (vec![0.0; nt], vec![0.0; nt]);
        for (i, qi) in q.iter_mut().enumerate() {
            let t = i / tile;
            let v = rng.int_in(-7, 7);
            if v >= 0 && pos[t] + v as f64 <= b {
                pos[t] += v as f64;
                *qi = v;
            } else if v < 0 && neg[t] + (-v) as f64 <= b {
                neg[t] += (-v) as f64;
                *qi = v;
            }
        }
        q
    }

    #[test]
    fn safe_codes_audit_clean() {
        let q = budget_codes(128, 32, 12, 8, 80);
        let r = audit_channel(&q, 8, 12, 32);
        assert!(r.clean(), "violations={}", r.violations);
        assert!(r.worst_utilization <= 1.0);
        let mut rng = Rng::new(81);
        let rf = audit_random(&q, 8, 12, 32, 200, &mut rng);
        assert!(rf.clean());
    }

    #[test]
    fn unsafe_codes_are_caught() {
        // all-max weights blow a 12-bit inner accumulator immediately
        let q = vec![7i64; 128];
        let r = audit_channel(&q, 8, 12, 32);
        assert!(!r.clean());
        assert!(r.worst_utilization > 1.0);
    }

    #[test]
    fn worst_case_dominates_fuzz() {
        // utilization from worst-case audit must upper-bound what any
        // random input can achieve
        let q = budget_codes(64, 64, 14, 8, 82);
        let wc = audit_channel(&q, 8, 14, 64);
        let nu = 255i64;
        let mut rng = Rng::new(83);
        for _ in 0..100 {
            let x: Vec<i64> = (0..64).map(|_| rng.int_in(0, nu)).collect();
            let v = crate::accum::simulator::dot_exact(&x, &q);
            let cap = ((1i64 << 13) - 1) as f64;
            assert!((v.abs() as f64 / cap) <= wc.worst_utilization + 1e-12);
        }
    }

    #[test]
    fn merge_accumulates() {
        let mut a = AuditReport { cases: 2, violations: 0, worst_utilization: 0.5 };
        let b = AuditReport { cases: 3, violations: 1, worst_utilization: 0.9 };
        a.merge(&b);
        assert_eq!(a.cases, 5);
        assert_eq!(a.violations, 1);
        assert!((a.worst_utilization - 0.9).abs() < 1e-12);
        assert!(!a.clean());
    }
}
