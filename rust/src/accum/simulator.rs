//! The integer MAC simulator.
//!
//! All arithmetic is carried in i64/i128 and *narrowed after every
//! addition* to model a P-bit register faithfully. Wraparound models
//! two's-complement hardware ([−2^{P−1}, 2^{P−1}−1]); saturation models
//! DSP-style clamping; `Checked` keeps exact values but counts every
//! step at which a P-bit register would have left its range (used by the
//! audit and by the paper-style "overflow rate" diagnostics).

/// Overflow behaviour of a register.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverflowMode {
    /// Two's-complement wraparound (most integer hardware).
    Wraparound,
    /// Saturating arithmetic.
    Saturate,
    /// Exact arithmetic, overflow events counted but not applied.
    Checked,
}

/// A register specification.
#[derive(Clone, Copy, Debug)]
pub struct AccumSpec {
    pub bits: u32,
    pub mode: OverflowMode,
}

impl AccumSpec {
    pub fn new(bits: u32, mode: OverflowMode) -> AccumSpec {
        assert!((2..=64).contains(&bits));
        AccumSpec { bits, mode }
    }

    pub fn wraparound(bits: u32) -> AccumSpec {
        AccumSpec::new(bits, OverflowMode::Wraparound)
    }

    pub fn saturate(bits: u32) -> AccumSpec {
        AccumSpec::new(bits, OverflowMode::Saturate)
    }

    pub fn checked(bits: u32) -> AccumSpec {
        AccumSpec::new(bits, OverflowMode::Checked)
    }

    /// Two's-complement bounds of the register.
    #[inline]
    pub fn min(&self) -> i64 {
        -(1i64 << (self.bits - 1))
    }

    #[inline]
    pub fn max(&self) -> i64 {
        (1i64 << (self.bits - 1)) - 1
    }

    /// Narrow a value into the register, returning (value, overflowed).
    #[inline]
    pub fn narrow(&self, v: i128) -> (i64, bool) {
        let lo = self.min() as i128;
        let hi = self.max() as i128;
        if v >= lo && v <= hi {
            return (v as i64, false);
        }
        match self.mode {
            OverflowMode::Wraparound => {
                let width = 1i128 << self.bits;
                let mut w = (v - lo).rem_euclid(width) + lo;
                if w > hi {
                    w -= width; // cannot happen after rem_euclid, defensive
                }
                (w as i64, true)
            }
            OverflowMode::Saturate => (if v < lo { lo as i64 } else { hi as i64 }, true),
            OverflowMode::Checked => (v.clamp(i64::MIN as i128, i64::MAX as i128) as i64, true),
        }
    }
}

/// Result of a simulated dot product.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DotOutcome {
    /// The value the hardware would produce.
    pub value: i64,
    /// Number of MAC steps at which the register left its range.
    pub overflows: usize,
}

/// Exact reference dot product (i128 internally, caller guarantees fit).
pub fn dot_exact(x: &[i64], w: &[i64]) -> i64 {
    debug_assert_eq!(x.len(), w.len());
    let mut acc: i128 = 0;
    for (a, b) in x.iter().zip(w.iter()) {
        acc += (*a as i128) * (*b as i128);
    }
    acc as i64
}

/// Simulate a monolithic P-bit accumulation of Σ x_i·w_i, narrowing
/// after every MAC (the per-step model the paper's Eq. 7-8 protect).
pub fn dot_monolithic(x: &[i64], w: &[i64], spec: AccumSpec) -> DotOutcome {
    debug_assert_eq!(x.len(), w.len());
    let mut acc: i64 = 0;
    let mut overflows = 0usize;
    for (a, b) in x.iter().zip(w.iter()) {
        let wide = acc as i128 + (*a as i128) * (*b as i128);
        let (v, ov) = spec.narrow(wide);
        acc = if spec.mode == OverflowMode::Checked { wide as i64 } else { v };
        overflows += ov as usize;
    }
    DotOutcome { value: acc, overflows }
}

/// Simulate the multi-stage datapath of Fig. 2b: tiles of `tile` inputs
/// each accumulate in an `inner` register; the per-tile partial sums are
/// then accumulated in the `outer` register.
pub fn dot_multistage(
    x: &[i64],
    w: &[i64],
    tile: usize,
    inner: AccumSpec,
    outer: AccumSpec,
) -> DotOutcome {
    debug_assert_eq!(x.len(), w.len());
    assert!(tile >= 1);
    let mut outer_acc: i64 = 0;
    let mut overflows = 0usize;
    for (xc, wc) in x.chunks(tile).zip(w.chunks(tile)) {
        let part = dot_monolithic(xc, wc, inner);
        overflows += part.overflows;
        let wide = outer_acc as i128 + part.value as i128;
        let (v, ov) = outer.narrow(wide);
        outer_acc = if outer.mode == OverflowMode::Checked { wide as i64 } else { v };
        overflows += ov as usize;
    }
    DotOutcome { value: outer_acc, overflows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::quick;
    use crate::util::rng::Rng;

    #[test]
    fn spec_bounds() {
        let s = AccumSpec::wraparound(8);
        assert_eq!(s.min(), -128);
        assert_eq!(s.max(), 127);
        let s16 = AccumSpec::wraparound(16);
        assert_eq!(s16.min(), -32768);
        assert_eq!(s16.max(), 32767);
    }

    #[test]
    fn narrow_wraparound_matches_twos_complement() {
        let s = AccumSpec::wraparound(8);
        assert_eq!(s.narrow(127), (127, false));
        assert_eq!(s.narrow(128), (-128, true));
        assert_eq!(s.narrow(129), (-127, true));
        assert_eq!(s.narrow(-128), (-128, false));
        assert_eq!(s.narrow(-129), (127, true));
        assert_eq!(s.narrow(256), (0, true));
        // i8 cast ground truth
        for v in -1000i128..1000 {
            let (nv, _) = s.narrow(v);
            assert_eq!(nv, v as i8 as i64, "v={v}");
        }
    }

    #[test]
    fn narrow_saturate() {
        let s = AccumSpec::saturate(8);
        assert_eq!(s.narrow(1000), (127, true));
        assert_eq!(s.narrow(-1000), (-128, true));
        assert_eq!(s.narrow(5), (5, false));
    }

    #[test]
    fn exact_dot_matches_naive() {
        let mut rng = Rng::new(70);
        for _ in 0..50 {
            let k = rng.int_in(1, 64) as usize;
            let x: Vec<i64> = (0..k).map(|_| rng.int_in(0, 255)).collect();
            let w: Vec<i64> = (0..k).map(|_| rng.int_in(-7, 7)).collect();
            let naive: i64 = x.iter().zip(&w).map(|(a, b)| a * b).sum();
            assert_eq!(dot_exact(&x, &w), naive);
        }
    }

    #[test]
    fn wide_register_equals_exact() {
        let mut rng = Rng::new(71);
        let x: Vec<i64> = (0..128).map(|_| rng.int_in(0, 255)).collect();
        let w: Vec<i64> = (0..128).map(|_| rng.int_in(-7, 7)).collect();
        let out = dot_monolithic(&x, &w, AccumSpec::wraparound(32));
        assert_eq!(out.value, dot_exact(&x, &w));
        assert_eq!(out.overflows, 0);
    }

    #[test]
    fn narrow_register_overflows_and_wraps() {
        // 100 * 255 = 25500 > 2^14/2-1=8191 -> overflow in 14-bit register
        let x = vec![255i64; 100];
        let w = vec![1i64; 100];
        let out = dot_monolithic(&x, &w, AccumSpec::wraparound(14));
        assert!(out.overflows > 0);
        assert_ne!(out.value, 25500);
        // checked mode: exact value preserved, overflow still flagged
        // (counts differ from wraparound mode because the wrapped state
        // follows a different trajectory after the first event)
        let chk = dot_monolithic(&x, &w, AccumSpec::checked(14));
        assert_eq!(chk.value, 25500);
        assert!(chk.overflows > 0);
    }

    #[test]
    fn multistage_matches_monolithic_when_tile_covers_all() {
        let mut rng = Rng::new(72);
        let k = 96;
        let x: Vec<i64> = (0..k).map(|_| rng.int_in(0, 255)).collect();
        let w: Vec<i64> = (0..k).map(|_| rng.int_in(-7, 7)).collect();
        let spec = AccumSpec::wraparound(20);
        let mono = dot_monolithic(&x, &w, spec);
        let multi = dot_multistage(&x, &w, k, spec, spec);
        assert_eq!(mono.value, multi.value);
    }

    #[test]
    fn prop_safe_codes_never_overflow() {
        // Any weights passing bounds::is_safe_multistage produce zero
        // overflow events for any inputs in range — the paper's guarantee
        // observed on the simulated hardware.
        quick(
            "simulator_respects_guarantee",
            |rng: &mut Rng| {
                let k = rng.int_in(8, 128) as usize;
                let tile = rng.int_in(4, 64) as usize;
                let n = rng.int_in(2, 8) as u32;
                let p = rng.int_in(10, 16) as u32;
                // build weights within per-tile side budget
                let b = crate::quant::bounds::side_budget(p, n, 0.0);
                let mut w = vec![0i64; k];
                let mut pos = vec![0.0; k.div_ceil(tile)];
                let mut neg = vec![0.0; k.div_ceil(tile)];
                for (i, wi) in w.iter_mut().enumerate() {
                    let t = i / tile;
                    let v = rng.int_in(-10, 10);
                    if v >= 0 && pos[t] + v as f64 <= b {
                        pos[t] += v as f64;
                        *wi = v;
                    } else if v < 0 && neg[t] + (-v) as f64 <= b {
                        neg[t] += (-v) as f64;
                        *wi = v;
                    }
                }
                let x: Vec<i64> = (0..k).map(|_| rng.int_in(0, (1 << n) - 1)).collect();
                (w, x, tile, p, n)
            },
            |(w, x, tile, p, _n)| {
                let p_outer = crate::quant::bounds::outer_bits(*p, w.len(), *tile);
                let out = dot_multistage(
                    x,
                    w,
                    *tile,
                    AccumSpec::wraparound(*p),
                    AccumSpec::wraparound(p_outer),
                );
                if out.overflows != 0 {
                    return Err(format!("{} overflows despite budget", out.overflows));
                }
                if out.value != dot_exact(x, w) {
                    return Err("wrapped value differs from exact".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn intermediate_wrap_even_if_final_fits() {
        // + then − : final sum fits, but the running max overflows.
        // 8-bit register: max 127.
        let x = vec![100i64, 100, 1];
        let w = vec![1i64, 1, -100];
        let out = dot_monolithic(&x, &w, AccumSpec::wraparound(8));
        assert!(out.overflows > 0, "running sum 200 must overflow 8-bit register");
        // exact result is 100 — and wraparound happens to recover it,
        // because two's complement addition is associative mod 2^P.
        assert_eq!(out.value, 100);
    }
}
