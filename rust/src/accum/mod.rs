//! Bit-accurate low-precision accumulator simulation.
//!
//! The paper's entire premise is that a P-bit accumulator either
//! overflows (corrupting results platform-dependently) or must be
//! guaranteed safe. This module is the "hardware" substitute for the
//! ARM/ASIC/FPGA datapaths the paper cites: an exact integer MAC pipeline
//! with configurable register width, overflow behaviour (two's-complement
//! wraparound / saturation / checked), and the multi-stage tiled datapath
//! of Fig. 2b. The overflow *audit* constructs the worst-case inputs of
//! Eq. 6 to verify guarantees bit-exactly.

pub mod audit;
pub mod simulator;

pub use audit::{audit_channel, audit_random, AuditReport};
pub use simulator::{dot_exact, AccumSpec, DotOutcome, OverflowMode};
