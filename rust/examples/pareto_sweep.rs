//! Full (M, N, P) design-space sweep for one model — the data behind the
//! paper's Figures 1/3 and Tables 4-7 (perplexity/accuracy, winning
//! (M, N), sparsity per Pareto-dominant point).
//!
//! Usage:
//!     cargo run --release --example pareto_sweep [model] [gpfq|optq]
//! LM models sweep perplexity; glyph models sweep top-1 accuracy.

use axe::coordinator::experiments::{
    design_space, pareto_frontier, render_frontier, run_img_config, run_lm_config, MetricKind,
};
use axe::coordinator::PipelineConfig;
use axe::eval::{load_corpus_split_or_synth, load_glyphs, synth_glyphs};
use axe::model::{load_named, Model};
use axe::quant::{AccumTarget, Algorithm, Method};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let name = args.get(1).cloned().unwrap_or_else(|| "pico-160k".to_string());
    let algo = Algorithm::parse(args.get(2).map(|s| s.as_str()).unwrap_or("gpfq"))
        .ok_or_else(|| anyhow::anyhow!("bad algorithm"))?;
    let p_values: Vec<u32> = vec![9, 10, 11, 12, 13, 14, 16, 18, 20, 22, 24];

    match load_named(&name)? {
        Model::Lm(base) => {
            let seq = base.cfg.max_seq;
            let train = load_corpus_split_or_synth("train", base.cfg.vocab);
            let val = load_corpus_split_or_synth("val", base.cfg.vocab);
            let calib: Vec<&[u16]> = train.chunks_exact(seq).take(12).collect();
            for (method, label) in axe::coordinator::experiments::methods() {
                let mut points = Vec::new();
                for (m, n) in design_space(3, 8) {
                    if method == Method::Naive {
                        let cfg = PipelineConfig::new(algo, method, m, n);
                        points.push(run_lm_config(&base, &calib, &val, seq, 24, &cfg)?);
                    } else {
                        for &p in &p_values {
                            let mut cfg = PipelineConfig::new(algo, method, m, n);
                            cfg.target = AccumTarget::Monolithic { p_bits: p };
                            points.push(run_lm_config(&base, &calib, &val, seq, 24, &cfg)?);
                        }
                    }
                }
                let f = pareto_frontier(&points, MetricKind::Perplexity);
                println!(
                    "{}",
                    render_frontier(
                        &format!("{name} · {} + {label}", algo.name()),
                        MetricKind::Perplexity,
                        &f
                    )
                );
            }
        }
        Model::Img(base) => {
            let train = load_glyphs("train").unwrap_or_else(|_| synth_glyphs(2000, 16, 10, 1));
            let test = load_glyphs("test").unwrap_or_else(|_| synth_glyphs(500, 16, 10, 2));
            let calib: Vec<&[f32]> = (0..256.min(train.len())).map(|i| train.row(i)).collect();
            for (method, label) in axe::coordinator::experiments::methods() {
                let mut points = Vec::new();
                for (m, n) in design_space(3, 8) {
                    if method == Method::Naive {
                        let cfg = PipelineConfig::new(algo, method, m, n);
                        points.push(run_img_config(&base, &calib, &test, &cfg)?);
                    } else {
                        for &p in &p_values {
                            let mut cfg = PipelineConfig::new(algo, method, m, n);
                            cfg.target = AccumTarget::Monolithic { p_bits: p };
                            points.push(run_img_config(&base, &calib, &test, &cfg)?);
                        }
                    }
                }
                let f = pareto_frontier(&points, MetricKind::Accuracy);
                println!(
                    "{}",
                    render_frontier(
                        &format!("{name} · {} + {label}", algo.name()),
                        MetricKind::Accuracy,
                        &f
                    )
                );
            }
        }
    }
    Ok(())
}
