//! End-to-end driver (paper Table 1): multi-stage accumulation across
//! the pico-LM ladder. Loads the real trained zoo, calibrates on the
//! real corpus, quantizes every layer with GPFQ* (memory-efficient) and
//! OPTQ under W4A8 / 16-bit inner accumulators at T ∈ {64, 128}, and
//! reports perplexity against the unconstrained base and the float
//! model — plus per-stage wall-clock timings, proving all layers of the
//! stack compose.
//!
//!     cargo run --release --example llm_scaling [--algo gpfq*|optq] [--models a,b,c]

use axe::coordinator::experiments::run_lm_config;
use axe::coordinator::PipelineConfig;
use axe::eval::{load_corpus_split_or_synth, perplexity};
use axe::model::{load_named, Model};
use axe::quant::{AccumTarget, Algorithm, Method};
use axe::util::argparse::Args;
use axe::util::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let algos: Vec<Algorithm> = args
        .str_list_or("algo", &["gpfq*", "optq"])
        .iter()
        .filter_map(|s| Algorithm::parse(s))
        .collect();
    let models = args.str_list_or(
        "models",
        &["pico-70k", "pico-160k", "pico-410k", "pico-1m", "pico-2m"],
    );
    let tiles = args.usize_list_or("tiles", &[64, 128]);
    let p_inner = args.u32_or("acc-bits", 16);

    for algo in algos {
        println!("\n### {} — W4A8, {p_inner}-bit inner accumulators\n", algo.name());
        let mut headers = vec!["model".to_string(), "params".into(), "float".into(), "base".into()];
        for t in &tiles {
            headers.push(format!("{t}x{p_inner}b"));
        }
        let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(&headers_ref);
        for name in &models {
            let Model::Lm(base) = load_named(name)? else { continue };
            let seq = base.cfg.max_seq;
            let train = load_corpus_split_or_synth("train", base.cfg.vocab);
            let val = load_corpus_split_or_synth("val", base.cfg.vocab);
            let calib: Vec<&[u16]> = train.chunks_exact(seq).take(12).collect();
            let t0 = std::time::Instant::now();
            let float_ppl = perplexity(&base, &val, seq, 24).ppl;
            let eval_s = t0.elapsed().as_secs_f64();

            let base_cfg = PipelineConfig::new(algo, Method::Naive, 4, 8);
            let base_pt = run_lm_config(&base, &calib, &val, seq, 24, &base_cfg)?;
            let mut row = vec![
                name.clone(),
                format!("{}", base.cfg.param_count()),
                format!("{float_ppl:.1}"),
                format!("{:.1}", base_pt.metric),
            ];
            let mut quant_s = base_pt.seconds;
            for &t in &tiles {
                let mut cfg = PipelineConfig::new(algo, Method::Axe, 4, 8);
                cfg.target = AccumTarget::MultiStage { p_inner, tile: t };
                let pt = run_lm_config(&base, &calib, &val, seq, 24, &cfg)?;
                assert!(pt.safe, "AXE must be provably safe");
                row.push(format!("{:.1}", pt.metric));
                quant_s += pt.seconds;
            }
            table.row(&row);
            eprintln!(
                "  [{name}] eval {eval_s:.1}s, quantization {quant_s:.1}s ({} layers/cfg)",
                base.cfg.n_layers * 6
            );
        }
        println!("{}", table.render());
    }
    println!(
        "\nExpected shape (paper Table 1): the gap between the constrained\n\
         columns and `base` shrinks as the ladder widens — T is fixed while\n\
         K grows, so capacity grows without tightening the constraint."
    );
    Ok(())
}
