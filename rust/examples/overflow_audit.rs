//! Overflow audit (paper §2.2 motivation + the Eq. 6 guarantee):
//!
//! 1. quantize with AXE for a small accumulator and prove — via the
//!    analytic worst-case inputs of Eq. 6 AND a large randomized fuzz
//!    through the bit-accurate wraparound simulator — that no dot
//!    product can overflow;
//! 2. quantize *without* constraints, run the same model on the same
//!    narrow datapath, and watch wraparound destroy perplexity.
//!
//!     cargo run --release --example overflow_audit [model]

use axe::coordinator::{quantize_transformer, DatapathMode, PipelineConfig};
use axe::eval::{load_corpus_split_or_synth, perplexity};
use axe::model::{load_named, Linear, Model};
use axe::quant::{AccumTarget, Algorithm, Method};
use axe::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "pico-160k".to_string());
    let Model::Lm(base) = load_named(&name)? else {
        anyhow::bail!("{name} is not an LM")
    };
    let seq = base.cfg.max_seq;
    let train = load_corpus_split_or_synth("train", base.cfg.vocab);
    let val = load_corpus_split_or_synth("val", base.cfg.vocab);
    let calib: Vec<&[u16]> = train.chunks_exact(seq).take(12).collect();
    let float_ppl = perplexity(&base, &val, seq, 24).ppl;
    let p = 16u32;
    let tile = 64usize;

    // --- constrained: AXE W4A8 @ 64x16b, faithful wraparound datapath
    let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
    cfg.target = AccumTarget::MultiStage { p_inner: p, tile };
    cfg.datapath = DatapathMode::Faithful;
    let mut constrained = base.clone();
    let report = quantize_transformer(&mut constrained, &calib, &cfg)?;
    println!("== AXE-constrained model (W4A8, {tile}x{p}b) ==");
    println!("worst-case audit: {} violations / {} cases (max util {:.3})",
        report.audit.violations, report.audit.cases, report.audit.worst_utilization);

    // deep randomized fuzz of every channel through the simulator
    let mut rng = Rng::new(42);
    let (mut cases, mut violations) = (0usize, 0usize);
    for lname in constrained.linear_names() {
        if let Some(Linear::Quant(q)) = constrained.get_linear(&lname) {
            for o in 0..q.out_dim {
                let codes: Vec<i64> =
                    q.codes[o * q.in_dim..(o + 1) * q.in_dim].iter().map(|&c| c as i64).collect();
                let r = axe::accum::audit_random(&codes, 8, p, tile, 20, &mut rng);
                cases += r.cases;
                violations += r.violations;
            }
        }
    }
    println!("fuzz audit      : {violations} violations / {cases} random input vectors");
    let ppl_c = perplexity(&constrained, &val, seq, 24);
    println!("faithful-datapath PPL: {:.2} (float {:.2}), overflow events during eval: {}",
        ppl_c.ppl, float_ppl, ppl_c.overflows);
    assert_eq!(ppl_c.overflows, 0);

    // --- unconstrained on a *narrow* register. Note: at K ≤ 224 random
    // W4A8 data rarely drives a 16-bit register past its range — which
    // is exactly why FBGEMM-style libraries "usually get away with it"
    // (paper §3.3) — but the worst-case audit proves it CAN overflow,
    // and at 12 bits the corruption is immediate and observable.
    let p_demo = 12u32;
    println!("\n== unconstrained model forced onto a {p_demo}-bit register ==");
    let mut cfg_u = PipelineConfig::new(Algorithm::Optq, Method::Naive, 4, 8);
    cfg_u.datapath = DatapathMode::Faithful;
    cfg_u.force_eval_bits = Some(p_demo);
    let mut unconstrained = base.clone();
    let report_u = quantize_transformer(&mut unconstrained, &calib, &cfg_u)?;
    println!("worst-case audit of unconstrained codes at {p_demo}b: utilization would be {:.1}x",
        report_u.audit.worst_utilization
            * ((1u64 << (report_u_cap(&report_u) - 1)) - 1) as f64
            / ((1u64 << (p_demo - 1)) - 1) as f64);
    let ppl_u = perplexity(&unconstrained, &val, seq, 24);
    println!("faithful-datapath PPL: {:.2}, overflow events during eval: {}",
        ppl_u.ppl, ppl_u.overflows);

    // --- AXE constrained for that same narrow register. A 12-bit inner
    // register pairs with a shorter tile (8) — the hardware trade the
    // multi-stage formulation exposes (Eq. 22).
    let tile12 = 8usize;
    let mut cfg_c12 = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
    cfg_c12.target = AccumTarget::MultiStage { p_inner: p_demo, tile: tile12 };
    cfg_c12.datapath = DatapathMode::Faithful;
    let mut constrained12 = base.clone();
    let rep12 = quantize_transformer(&mut constrained12, &calib, &cfg_c12)?;
    let ppl_c12 = perplexity(&constrained12, &val, seq, 24);
    println!("\n== AXE model constrained for {tile12}x{p_demo}b ==");
    println!("audit: {} violations; faithful PPL {:.2}, overflow events: {}",
        rep12.audit.violations, ppl_c12.ppl, ppl_c12.overflows);

    println!("\nsummary: float {float_ppl:.1}");
    println!("  AXE      @{tile}x{p}b   : {:.1} PPL, {} overflows (guaranteed)", ppl_c.ppl, ppl_c.overflows);
    println!("  AXE      @{tile12}x{p_demo}b   : {:.1} PPL, {} overflows (guaranteed)", ppl_c12.ppl, ppl_c12.overflows);
    println!("  unconstr @{p_demo}b        : {:.1} PPL, {} overflows", ppl_u.ppl, ppl_u.overflows);
    if ppl_u.overflows > 0 && ppl_u.ppl > 2.0 * ppl_c12.ppl {
        println!("=> wraparound corruption exactly where the paper predicts it");
    }
    Ok(())
}

/// The unconstrained model's audited register width (Eq. 3 P* of the
/// widest layer) — used to rescale its utilization to the demo width.
fn report_u_cap(report: &axe::coordinator::PipelineReport) -> u32 {
    // P* for W4A8 at the widest K in the pico family is ~21; recover it
    // from the report name-free by bounding with Eq. 3 on the widest
    // layer the audit saw.
    let k_max = report.layers.iter().map(|l| l.k).max().unwrap_or(1);
    axe::quant::datatype_min_bits(k_max, 8, 4, false)
}
