//! Quickstart: quantize a trained pico-LM with OPTQ+AXE for a 16-bit
//! multi-stage accumulator, verify the overflow-avoidance guarantee, and
//! compare perplexity against the float model.
//!
//! Run after `make artifacts`:
//!     cargo run --release --example quickstart

use axe::accum::audit_random;
use axe::coordinator::{quantize_transformer, PipelineConfig};
use axe::eval::{load_corpus_split_or_synth, perplexity};
use axe::model::{load_named, Linear, Model};
use axe::quant::{AccumTarget, Algorithm, Method};
use axe::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "pico-160k".to_string());
    let Model::Lm(mut model) = load_named(&name)? else {
        anyhow::bail!("{name} is not an LM");
    };
    println!("loaded {name}: {} params, {} layers", model.cfg.param_count(), model.cfg.n_layers);

    let train = load_corpus_split_or_synth("train", model.cfg.vocab);
    let val = load_corpus_split_or_synth("val", model.cfg.vocab);
    let seq = model.cfg.max_seq;
    let calib: Vec<&[u16]> = train.chunks_exact(seq).take(16).collect();

    let float_ppl = perplexity(&model, &val, seq, 32).ppl;
    println!("float perplexity      : {float_ppl:.2}");

    // W4A8, tiles of 64 inputs, 16-bit inner accumulators (paper Table 1)
    let mut cfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
    cfg.target = AccumTarget::MultiStage { p_inner: 16, tile: 64 };
    let report = quantize_transformer(&mut model, &calib, &cfg)?;
    println!("quantized             : {}", report.config);
    println!("quantization time     : {:.2}s", report.total_seconds);
    println!("weight sparsity       : {:.1}%", report.sparsity() * 100.0);

    let q_ppl = perplexity(&model, &val, seq, 32).ppl;
    println!("quantized perplexity  : {q_ppl:.2}");

    // The guarantee, checked two ways:
    // 1. analytic worst-case audit (Eq. 6) — done inside the pipeline
    println!(
        "worst-case audit      : {} violations over {} cases (util {:.3})",
        report.audit.violations, report.audit.cases, report.audit.worst_utilization
    );
    // 2. randomized fuzzing through the bit-accurate simulator
    let mut rng = Rng::new(0xF00D);
    let mut fuzz_cases = 0usize;
    let mut fuzz_violations = 0usize;
    for lname in model.linear_names() {
        if let Some(Linear::Quant(q)) = model.get_linear(&lname) {
            for o in 0..q.out_dim.min(8) {
                let codes: Vec<i64> =
                    q.codes[o * q.in_dim..(o + 1) * q.in_dim].iter().map(|&c| c as i64).collect();
                let r = audit_random(&codes, 8, 16, 64, 50, &mut rng);
                fuzz_cases += r.cases;
                fuzz_violations += r.violations;
            }
        }
    }
    println!("fuzz audit            : {fuzz_violations} violations over {fuzz_cases} random inputs");
    assert!(report.guaranteed_safe() && fuzz_violations == 0);
    println!("=> overflow-free at 64x16b, PPL {float_ppl:.2} -> {q_ppl:.2}");
    Ok(())
}
