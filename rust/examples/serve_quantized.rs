//! Mini serving loop: batched greedy generation served two ways —
//! (a) the float model AOT-compiled by JAX and executed through the
//!     PJRT runtime (the L2→runtime path), and
//! (b) the rust-native AXE-quantized model on the integer datapath
//!     (the L3 path) —
//! reporting latency, throughput and per-token agreement between them.
//!
//! Requires `make artifacts` (weights + pico-160k_fwd.hlo.txt).
//!
//!     cargo run --release --example serve_quantized

use axe::coordinator::{quantize_transformer, PipelineConfig};
use axe::eval::load_corpus_split_or_synth;
use axe::model::{load_named, read_f32_bin_any, Model};
use axe::quant::{AccumTarget, Algorithm, Method};
use axe::runtime::{F32Input, Runtime};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let name = "pico-160k";
    let Model::Lm(float_model) = load_named(name)? else {
        anyhow::bail!("missing model")
    };
    let cfg_m = float_model.cfg.clone();
    let (batch, seq, vocab) = (4usize, cfg_m.max_seq, cfg_m.vocab);

    // ---- PJRT path: load the AOT artifact and its parameter list
    let rt = Runtime::new()?;
    let manifest = axe::runtime::load_manifest()?;
    let entry = manifest
        .req_arr("artifacts")?
        .iter()
        .find(|a| a.get("name").and_then(|n| n.as_str()) == Some(&format!("{name}_fwd")))
        .ok_or_else(|| anyhow::anyhow!("{name}_fwd artifact missing — run `make artifacts`"))?
        .clone();
    let param_names: Vec<String> = entry
        .req_arr("params")?
        .iter()
        .filter_map(|p| p.as_str().map(|s| s.to_string()))
        .collect();
    let weights_dir = axe::artifacts_dir().join("weights").join(name);
    let mut param_inputs: Vec<(Vec<f32>, Vec<usize>)> = Vec::new();
    let model_manifest = axe::util::json::Json::parse(&std::fs::read_to_string(
        weights_dir.join("manifest.json"),
    )?)
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    for pn in &param_names {
        let shape: Vec<usize> = model_manifest
            .get("tensors")
            .and_then(|t| t.get(pn))
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing tensor {pn}"))?
            .iter()
            .map(|d| d.as_usize().unwrap_or(0))
            .collect();
        let data = read_f32_bin_any(&weights_dir.join(format!("{pn}.bin")))?;
        param_inputs.push((data, shape));
    }
    println!("PJRT platform: {}, artifact {} params", rt.platform(), param_names.len());

    // ---- quantized rust path
    let train = load_corpus_split_or_synth("train", vocab);
    let calib: Vec<&[u16]> = train.chunks_exact(seq).take(12).collect();
    let mut qcfg = PipelineConfig::new(Algorithm::Optq, Method::Axe, 4, 8);
    qcfg.target = AccumTarget::MultiStage { p_inner: 16, tile: 64 };
    let mut qmodel = float_model.clone();
    let report = quantize_transformer(&mut qmodel, &calib, &qcfg)?;
    println!("quantized model ready ({}, safe={})", report.config, report.guaranteed_safe());

    // ---- serve a few batched generation requests
    let val = load_corpus_split_or_synth("val", vocab);
    let prompts: Vec<Vec<u16>> =
        (0..batch).map(|i| val[i * seq..i * seq + seq].to_vec()).collect();
    let gen_tokens = 16usize;

    // PJRT float generation (recompiles nothing: fixed (B, S) shape,
    // sliding window)
    let t0 = Instant::now();
    let mut pjrt_out: Vec<Vec<u16>> = prompts.clone();
    for _ in 0..gen_tokens {
        let mut toks = vec![0f32; batch * seq];
        for (b, p) in pjrt_out.iter().enumerate() {
            let window = &p[p.len() - seq..];
            for (s, &t) in window.iter().enumerate() {
                toks[b * seq + s] = t as f32;
            }
        }
        let mut inputs = vec![F32Input::new(toks, &[batch, seq])];
        for (data, shape) in &param_inputs {
            inputs.push(F32Input::new(data.clone(), shape));
        }
        let outs = rt.run_f32(&format!("{name}_fwd"), &inputs)?;
        let logits = &outs[0]; // (B, S, V)
        for (b, p) in pjrt_out.iter_mut().enumerate() {
            let last = &logits[(b * seq + seq - 1) * vocab..(b * seq + seq) * vocab];
            let next = argmax(last) as u16;
            p.push(next);
        }
    }
    let pjrt_s = t0.elapsed().as_secs_f64();

    // rust quantized generation (same full-window recompute as the PJRT
    // path, for an apples-to-apples per-token comparison)
    let t1 = Instant::now();
    let mut rust_out: Vec<Vec<u16>> = prompts.clone();
    for p in rust_out.iter_mut() {
        for _ in 0..gen_tokens {
            let window = &p[p.len() - seq..];
            let logits = qmodel.forward(window, None);
            let last = &logits[(seq - 1) * vocab..seq * vocab];
            p.push(argmax(last) as u16);
        }
    }
    let rust_s = t1.elapsed().as_secs_f64();

    // rust quantized generation through the continuous-batching engine
    // (the serving fast path): all requests share one KV arena, every
    // decode step is one fused qgemm dispatch per layer across the
    // whole in-flight batch
    use axe::coordinator::serve::{serve, Request, ServeQueue, ServeStats};
    let queue = ServeQueue::new();
    for (id, p) in prompts.iter().enumerate() {
        queue
            .submit(Request {
                id: id as u64,
                prompt: p[p.len() - seq / 2..].to_vec(),
                max_new_tokens: gen_tokens,
                ..Request::default()
            })
            .expect("unbounded queue accepts every submit");
    }
    queue.close();
    let t2 = Instant::now();
    serve(&qmodel, &queue, 1, batch);
    let kv_out = queue.drain();
    let kv_s = t2.elapsed().as_secs_f64();
    // overflow events are summed from the exact per-request counters
    let kv_stats = ServeStats::from_responses(&kv_out, kv_s);

    // agreement
    let mut agree = 0usize;
    for (a, b) in pjrt_out.iter().zip(rust_out.iter()) {
        for i in seq..a.len() {
            if a[i] == b[i] {
                agree += 1;
            }
        }
    }
    let total = batch * gen_tokens;
    println!("\nserved {batch} requests × {gen_tokens} tokens");
    println!(
        "PJRT float path : {:.3}s total, {:.1} tok/s, {:.1} ms/token-batch",
        pjrt_s,
        total as f64 / pjrt_s,
        1000.0 * pjrt_s / gen_tokens as f64
    );
    println!(
        "rust quant path : {:.3}s total, {:.1} tok/s",
        rust_s,
        total as f64 / rust_s
    );
    println!(
        "rust + batched KV arena : {:.3}s total, {:.1} tok/s ({:.1}x over recompute), \
         p99 {:.1} ms, overflow events {}",
        kv_s,
        kv_stats.tokens_per_s,
        rust_s / kv_s,
        kv_stats.p99_latency_s * 1e3,
        kv_stats.overflow_events
    );
    println!(
        "agreement       : {agree}/{total} generated tokens match ({:.0}%)",
        100.0 * agree as f64 / total as f64
    );
    Ok(())
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}
