//! §Perf probe: Newton–Schulz matrix-sqrt iteration count & wallclock,
//! spectral scaling (default) vs Frobenius scaling (AXE_SQRTM_FROB=1).
use axe::linalg::{sqrtm_psd, Mat};
use axe::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(1);
    for &(n, d) in &[(256usize, 256usize), (576, 768)] {
        let x = Mat::random_normal(n, d, &mut rng, 1.0);
        let mut a = x.gram();
        let md = a.diag().iter().sum::<f64>() / n as f64;
        a.add_diag(0.01 * md);
        let t0 = std::time::Instant::now();
        let r = sqrtm_psd(&a, 1e-11, 100).unwrap();
        println!("n={n}: {} iterations, {:.2}s", r.iterations, t0.elapsed().as_secs_f64());
    }
}
