"""L1 Pallas kernel: tiled quantized matmul with multi-stage low-precision
accumulation (paper Fig. 2b).

The grid's K dimension *is* the paper's tile loop: each (bm, bn, T)
block computes one tile's partial dot product, wraps it into the
P_I-bit inner register, and accumulates the running output block in the
P_O-bit outer register. On a real TPU this schedule maps to MXU passes
with VMEM-resident blocks; here it is lowered with interpret=True so the
CPU PJRT client (and the rust runtime) can execute the same HLO — see
DESIGN.md §Hardware-Adaptation.

VMEM budget per grid step (int32):
    bm*T + T*bn + bm*bn words = (bm + bn) * T + bm*bn
e.g. bm=bn=64, T=128: 64 KiB — comfortably under the ~16 MiB VMEM of a
TPU core, leaving room for double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wrap(v, bits: int):
    """Two's-complement wrap into a `bits`-bit register (int32 domain).

    The kernel's physical carrier is int32, so a register of ≥ 31 bits is
    exact here and the wrap is the identity (1 << 31 would also overflow
    the int32 modulus).
    """
    if bits >= 31:
        return v
    lo = -(1 << (bits - 1))
    width = 1 << bits
    return (v - lo) % width + lo


def _qmatmul_kernel(x_ref, w_ref, o_ref, *, p_inner: int, p_outer: int):
    """One grid step: tile partial product -> inner wrap -> outer wrap."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    part = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.int32)
    part = _wrap(part, p_inner)
    o_ref[...] = _wrap(o_ref[...] + part, p_outer)


def qmatmul(
    x,
    w,
    *,
    tile: int,
    p_inner: int,
    p_outer: int,
    block_m: int = 32,
    block_n: int = 32,
    interpret: bool = True,
):
    """Multi-stage quantized matmul via Pallas.

    x: (M, K) int32 activation codes; w: (K, N) int32 weight codes.
    K must be divisible by `tile`, M by block_m, N by block_n (the AOT
    path pads; the kernel itself stays power-of-two regular, as a Mosaic
    lowering would require).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    assert k % tile == 0, f"K={k} not divisible by tile={tile}"
    bm = min(block_m, m)
    bn = min(block_n, n)
    assert m % bm == 0 and n % bn == 0, f"M={m}/N={n} not divisible by blocks"
    grid = (m // bm, n // bn, k // tile)
    kernel = functools.partial(_qmatmul_kernel, p_inner=p_inner, p_outer=p_outer)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, tile), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, w)


def vmem_words(block_m: int, block_n: int, tile: int) -> int:
    """int32 words resident per grid step (for the DESIGN.md roofline
    estimate)."""
    return (block_m + block_n) * tile + block_m * block_n


def dequantize(acc, w_scales, x_scale, x_zero_point, w_code_sums):
    """Turn integer accumulator outputs into real values:
    y = s_w ⊙ s_x · (acc − z_x · Σ_k q) — the zero-point correction the
    rust QuantLinear applies (linear.rs)."""
    corrected = acc - x_zero_point * w_code_sums[None, :]
    return (w_scales[None, :] * x_scale) * corrected.astype(jnp.float32)
