"""Pure-jnp oracle for the multi-stage quantized matmul kernel.

This is the correctness ground truth for the Pallas kernel
(`qmatmul.py`) and mirrors the rust accumulator simulator
(`rust/src/accum/simulator.rs`). Two's-complement addition is
associative mod 2^P, so wrapping each tile's partial sum once is
bit-identical to wrapping after every MAC — the property the rust
tests also rely on.
"""

import jax.numpy as jnp
import numpy as np


def wrap_twos_complement(v, bits: int):
    """Wrap integer values into a `bits`-bit two's-complement register.

    Works on int32/int64 jnp or numpy arrays. Uses floor-mod so negative
    values wrap exactly like hardware.
    """
    lo = -(1 << (bits - 1))
    width = 1 << bits
    return (v - lo) % width + lo


def qmatmul_ref(x, w, tile: int, p_inner: int, p_outer: int):
    """Reference multi-stage quantized matmul.

    x: (M, K) integer activation codes (unsigned range, stored int32).
    w: (K, N) integer weight codes (signed alphabet, stored int32).
    Each K-tile of size `tile` accumulates in a p_inner-bit register;
    the partial sums accumulate in a p_outer-bit register (paper Fig. 2b,
    Eq. 22).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    # numpy int64 on purpose: jax may run with x64 disabled, which would
    # silently truncate the exact arithmetic this oracle depends on.
    x64 = np.asarray(x, np.int64)
    w64 = np.asarray(w, np.int64)
    acc = np.zeros((m, n), np.int64)
    for start in range(0, k, tile):
        stop = min(start + tile, k)
        part = x64[:, start:stop] @ w64[start:stop, :]
        part = np.asarray(wrap_twos_complement(part, p_inner))
        acc = np.asarray(wrap_twos_complement(acc + part, p_outer))
    return jnp.asarray(acc, jnp.int32)


def qmatmul_exact(x, w):
    """Exact int64 matmul (what a wide accumulator would produce)."""
    return np.asarray(x, np.int64) @ np.asarray(w, np.int64)


def overflow_count_ref(x, w, tile: int, p_inner: int, p_outer: int) -> int:
    """Count tile partials / outer sums that left their register range
    (diagnostic mirror of the rust `Checked` mode, counted per tile)."""
    x64 = np.asarray(x, np.int64)
    w64 = np.asarray(w, np.int64)
    m, k = x64.shape
    cap_i = (1 << (p_inner - 1)) - 1
    cap_o = (1 << (p_outer - 1)) - 1
    count = 0
    acc = np.zeros((m, w64.shape[1]), np.int64)
    for start in range(0, k, tile):
        stop = min(start + tile, k)
        part = x64[:, start:stop] @ w64[start:stop, :]
        count += int((np.abs(part) > cap_i).sum())
        part = np.asarray(wrap_twos_complement(part, p_inner))
        acc = acc + part
        count += int((np.abs(acc) > cap_o).sum())
        acc = np.asarray(wrap_twos_complement(acc, p_outer))
    return count
