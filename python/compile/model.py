"""L2: the pico-LM transformer and glyph MLP in pure JAX.

Semantics mirror the rust inference substrate exactly (tanh-GELU,
LayerNorm eps 1e-5, learned positions, optional parallel residual,
float head without bias); a parity test on exported weights checks
rust-vs-jax logits agree. Parameters live in a flat dict keyed by the
same tensor names the rust loader reads (`b0.wq.w`, `ln_f.g`, ...),
with weight matrices stored [out, in].

The quantized forward path (`lm_forward_quant`) routes every linear
through the L1 Pallas kernel so the whole multi-stage integer datapath
lowers into one HLO artifact.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.qmatmul import dequantize, qmatmul


@dataclass(frozen=True)
class LmConfig:
    name: str
    vocab: int = 64
    d_model: int = 56
    n_layers: int = 4
    n_heads: int = 7
    d_ff: int = 224
    max_seq: int = 64
    act: str = "gelu"  # "gelu" | "relu"
    parallel_residual: bool = True

    def param_specs(self):
        """(name, shape) pairs — the manifest's tensor table."""
        d, ff, v, s = self.d_model, self.d_ff, self.vocab, self.max_seq
        specs = [("embed", (v, d)), ("pos", (s, d))]
        for b in range(self.n_layers):
            p = f"b{b}"
            specs += [
                (f"{p}.ln1.g", (d,)),
                (f"{p}.ln1.b", (d,)),
                (f"{p}.ln2.g", (d,)),
                (f"{p}.ln2.b", (d,)),
            ]
            for lin, (o, i) in [
                ("wq", (d, d)),
                ("wk", (d, d)),
                ("wv", (d, d)),
                ("wo", (d, d)),
                ("fc1", (ff, d)),
                ("fc2", (d, ff)),
            ]:
                specs += [(f"{p}.{lin}.w", (o, i)), (f"{p}.{lin}.b", (o,))]
        specs += [("ln_f.g", (d,)), ("ln_f.b", (d,)), ("head.w", (v, d))]
        return specs


def lm_init(cfg: LmConfig, key) -> dict:
    """Initialize parameters (GPT-2-style scaled normal)."""
    params = {}
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        if name.endswith(".g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(".b") and len(shape) == 1:
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            std = 0.08 if name in ("embed", "pos") else 0.06
            params[name] = jax.random.normal(sub, shape, jnp.float32) * std
    return params


def _act(cfg: LmConfig, x):
    if cfg.act == "relu":
        return jax.nn.relu(x)
    return jax.nn.gelu(x, approximate=True)  # tanh approximation == rust


def _ln(x, g, b, eps=1e-5):
    mean = x.mean(-1, keepdims=True)
    var = ((x - mean) ** 2).mean(-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * g + b


def _linear(p, prefix, x):
    # weights stored [out, in]; x is (..., in)
    return x @ p[f"{prefix}.w"].T + p[f"{prefix}.b"]


def _attention(cfg: LmConfig, q, k, v):
    s, d = q.shape[-2], q.shape[-1]
    h = cfg.n_heads
    hd = d // h
    qh = q.reshape(*q.shape[:-1], h, hd)
    kh = k.reshape(*k.shape[:-1], h, hd)
    vh = v.reshape(*v.shape[:-1], h, hd)
    scores = jnp.einsum("...qhd,...khd->...hqk", qh, kh) / jnp.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, -1e9)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("...hqk,...khd->...qhd", attn, vh)
    return out.reshape(*q.shape)


def lm_forward(cfg: LmConfig, params: dict, tokens):
    """Float forward: tokens (B, S) int -> logits (B, S, vocab)."""
    tokens = tokens.astype(jnp.int32)
    s = tokens.shape[-1]
    h = params["embed"][tokens] + params["pos"][:s]
    for bi in range(cfg.n_layers):
        p = f"b{bi}"
        ln1 = _ln(h, params[f"{p}.ln1.g"], params[f"{p}.ln1.b"])
        q = _linear(params, f"{p}.wq", ln1)
        k = _linear(params, f"{p}.wk", ln1)
        v = _linear(params, f"{p}.wv", ln1)
        mix = _attention(cfg, q, k, v)
        attn_out = _linear(params, f"{p}.wo", mix)
        if not cfg.parallel_residual:
            h = h + attn_out
        ln2 = _ln(h, params[f"{p}.ln2.g"], params[f"{p}.ln2.b"])
        ff = _act(cfg, _linear(params, f"{p}.fc1", ln2))
        ff_out = _linear(params, f"{p}.fc2", ff)
        h = h + attn_out + ff_out if cfg.parallel_residual else h + ff_out
    h = _ln(h, params["ln_f.g"], params["ln_f.b"])
    return h @ params["head.w"].T


def lm_loss(cfg: LmConfig, params: dict, tokens):
    """Mean next-token cross entropy over a batch (B, S)."""
    logits = lm_forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:].astype(jnp.int32)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)
    return nll.mean()


# ---------------------------------------------------------------------------
# Quantized path — routes linears through the Pallas kernel.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class QuantSpec:
    """Static quantization metadata for the AOT quantized forward."""

    act_bits: int = 8
    tile: int = 64
    p_inner: int = 16
    p_outer: int = 20
    block_m: int = 16
    block_n: int = 8


def quant_linear_kernel(x, w_codes, w_scales, x_scale, x_zp, spec: QuantSpec):
    """Quantize activations, run the Pallas integer kernel, dequantize.

    x: (M, K) float; w_codes: (K, N) int32; returns (M, N) float.
    Shapes must satisfy the kernel's divisibility constraints (the AOT
    wrapper pads K / M / N as needed).
    """
    nu = (1 << spec.act_bits) - 1
    codes = jnp.clip(jnp.round(x / x_scale) + x_zp, 0, nu).astype(jnp.int32)
    acc = qmatmul(
        codes,
        w_codes,
        tile=spec.tile,
        p_inner=spec.p_inner,
        p_outer=spec.p_outer,
        block_m=spec.block_m,
        block_n=spec.block_n,
    )
    sums = w_codes.sum(axis=0)
    return dequantize(acc, w_scales, x_scale, x_zp, sums)


def pad_to(x, axis: int, mult: int):
    """Zero-pad `axis` of x up to a multiple of `mult`."""
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


# ---------------------------------------------------------------------------
# Glyph MLP
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MlpConfig:
    name: str
    input_dim: int = 256
    hidden: tuple = (128, 128)
    classes: int = 10
    act: str = "relu"
    residual: bool = False

    def param_specs(self):
        specs = []
        prev = self.input_dim
        for i, hdim in enumerate(self.hidden):
            specs += [(f"l{i}.w", (hdim, prev)), (f"l{i}.b", (hdim,))]
            prev = hdim
        specs += [("head.w", (self.classes, prev)), ("head.b", (self.classes,))]
        return specs


def mlp_init(cfg: MlpConfig, key) -> dict:
    params = {}
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        if name.endswith(".b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[1]
            params[name] = jax.random.normal(sub, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)
    return params


def mlp_forward(cfg: MlpConfig, params: dict, x):
    """x (B, input_dim) -> logits (B, classes). Mirrors rust Mlp::forward:
    activation after every hidden layer, optional equal-width residual."""
    h = x
    for i in range(len(cfg.hidden)):
        out = h @ params[f"l{i}.w"].T + params[f"l{i}.b"]
        out = jax.nn.relu(out) if cfg.act == "relu" else jax.nn.gelu(out, approximate=True)
        if cfg.residual and out.shape == h.shape:
            out = out + h
        h = out
    return h @ params["head.w"].T + params["head.b"]


def mlp_loss(cfg: MlpConfig, params: dict, x, y):
    logits = mlp_forward(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1).mean()


# ---------------------------------------------------------------------------
# The model zoo (DESIGN.md §2 substitution table)
# ---------------------------------------------------------------------------

LM_ZOO = {
    # Pythia-like ladder: width grows, the accumulator experiments' K with it
    "pico-70k": LmConfig("pico-70k", d_model=40, n_layers=3, n_heads=5, d_ff=160),
    "pico-160k": LmConfig("pico-160k", d_model=56, n_layers=4, n_heads=7, d_ff=224),
    "pico-410k": LmConfig("pico-410k", d_model=80, n_layers=5, n_heads=10, d_ff=320),
    "pico-1m": LmConfig("pico-1m", d_model=112, n_layers=7, n_heads=14, d_ff=448),
    "pico-2m": LmConfig("pico-2m", d_model=144, n_layers=9, n_heads=18, d_ff=576),
    # family variants (OPT-ish / GPT2-ish) at the 160k point
    "pico-160k-opt": LmConfig(
        "pico-160k-opt", d_model=56, n_layers=4, n_heads=7, d_ff=224, act="relu",
        parallel_residual=False,
    ),
    "pico-160k-gpt2": LmConfig(
        "pico-160k-gpt2", d_model=56, n_layers=4, n_heads=7, d_ff=224, act="gelu",
        parallel_residual=False,
    ),
}

IMG_ZOO = {
    "glyph-mlp": MlpConfig("glyph-mlp", hidden=(128, 128)),
    "glyph-res": MlpConfig("glyph-res", hidden=(96, 96, 96, 96, 96, 96), residual=True),
    "glyph-bottleneck": MlpConfig("glyph-bottleneck", hidden=(160, 48, 160)),
}


def param_count(params: dict) -> int:
    return int(sum(np.prod(v.shape) for v in params.values()))
