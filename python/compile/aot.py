"""AOT lowering: JAX/Pallas → HLO text artifacts for the rust runtime.

Interchange is HLO *text* (never `.serialize()`): jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts:
  <model>_fwd.hlo.txt    — float forward logits(tokens, *params);
                           params passed as inputs in sorted-name order
                           (listed in manifest.json) so the rust side
                           feeds the same tensors it loaded from the zoo.
  qmatmul_tT_pP.hlo.txt  — the standalone L1 Pallas kernel for a
                           canonical shape (integer in/out).
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.qmatmul import qmatmul
from .model import LM_ZOO, lm_forward


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_lm_forward(cfg, weights_dir: pathlib.Path, out_dir: pathlib.Path, batch: int):
    """Lower logits = fwd(tokens, *params) with params as inputs."""
    manifest = json.loads((weights_dir / cfg.name / "manifest.json").read_text())
    names = sorted(manifest["tensors"].keys())
    shapes = [tuple(manifest["tensors"][n]) for n in names]

    def fwd(tokens, *flat_params):
        params = dict(zip(names, flat_params))
        return (lm_forward(cfg, params, tokens.astype(jnp.int32)),)

    tok_spec = jax.ShapeDtypeStruct((batch, cfg.max_seq), jnp.float32)
    param_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    lowered = jax.jit(fwd).lower(tok_spec, *param_specs)
    text = to_hlo_text(lowered)
    name = f"{cfg.name}_fwd"
    (out_dir / f"{name}.hlo.txt").write_text(text)
    return {
        "name": name,
        "kind": "lm_forward",
        "model": cfg.name,
        "batch": batch,
        "seq": cfg.max_seq,
        "vocab": cfg.vocab,
        "params": names,
        "tokens_dtype": "f32",
    }


def export_qmatmul(out_dir: pathlib.Path, m: int, k: int, n: int, tile: int, p_inner: int,
                   p_outer: int):
    def fn(x, w):
        return (
            qmatmul(x, w, tile=tile, p_inner=p_inner, p_outer=p_outer, block_m=min(32, m),
                    block_n=min(32, n)),
        )

    xs = jax.ShapeDtypeStruct((m, k), jnp.int32)
    ws = jax.ShapeDtypeStruct((k, n), jnp.int32)
    lowered = jax.jit(fn).lower(xs, ws)
    text = to_hlo_text(lowered)
    name = f"qmatmul_t{tile}_p{p_inner}"
    (out_dir / f"{name}.hlo.txt").write_text(text)
    return {
        "name": name,
        "kind": "qmatmul",
        "m": m,
        "k": k,
        "n": n,
        "tile": tile,
        "p_inner": p_inner,
        "p_outer": p_outer,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/hlo")
    ap.add_argument("--weights", default="../artifacts/weights")
    ap.add_argument("--models", default="pico-160k", help="comma list of LMs to export")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    weights_dir = pathlib.Path(args.weights)

    entries = []
    for mname in args.models.split(","):
        mname = mname.strip()
        if not mname:
            continue
        cfg = LM_ZOO[mname]
        if not (weights_dir / mname / "manifest.json").exists():
            print(f"skipping {mname}: weights not trained yet")
            continue
        entries.append(export_lm_forward(cfg, weights_dir, out_dir, args.batch))
        print(f"exported {mname}_fwd")

    # canonical kernel artifacts (Table-1 tiles)
    for tile, p_inner in [(64, 16), (128, 16)]:
        k = 256
        p_outer = p_inner + int(np.ceil(np.log2(max(1, k // tile))))
        entries.append(export_qmatmul(out_dir, m=32, k=k, n=64, tile=tile, p_inner=p_inner,
                                      p_outer=p_outer))
        print(f"exported qmatmul_t{tile}_p{p_inner}")

    (out_dir / "manifest.json").write_text(json.dumps({"artifacts": entries}, indent=2))
    print(f"wrote {len(entries)} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
