"""Train the model zoo and export weights for the rust coordinator.

Own Adam (no optax in the image), jit-compiled update with donated
params. Exports: raw little-endian f32 tensors + manifest.json per
model (the format rust/src/model/loader.rs reads), plus a parity bundle
(fixed input + jax logits) the rust integration tests check against.
"""

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .model import (
    IMG_ZOO,
    LM_ZOO,
    LmConfig,
    MlpConfig,
    lm_forward,
    lm_init,
    lm_loss,
    mlp_forward,
    mlp_init,
    mlp_loss,
    param_count,
)

# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros(())}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    mhat = {k: m[k] / (1 - b1**t) for k in params}
    vhat = {k: v[k] / (1 - b2**t) for k in params}
    new_params = {k: params[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps) for k in params}
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Training loops
# ---------------------------------------------------------------------------


def batches_lm(tokens: np.ndarray, seq: int, batch: int, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    max_start = len(tokens) - seq - 1
    for _ in range(steps):
        starts = rng.integers(0, max_start, batch)
        yield np.stack([tokens[s : s + seq] for s in starts]).astype(np.int32)


def train_lm(cfg: LmConfig, tokens: np.ndarray, steps: int, batch: int, lr: float, log):
    key = jax.random.PRNGKey(hash(cfg.name) % (2**31))
    params = lm_init(cfg, key)
    opt = adam_init(params)
    loss_grad = jax.jit(jax.value_and_grad(lambda p, b: lm_loss(cfg, p, b)))

    @jax.jit
    def step(params, opt, batch_tokens):
        loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, batch_tokens))(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    _ = loss_grad  # retained for profiling hooks
    t0 = time.time()
    losses = []
    for i, b in enumerate(batches_lm(tokens, cfg.max_seq, batch, steps, seed=42)):
        params, opt, loss = step(params, opt, jnp.array(b))
        losses.append(float(loss))
        if i % max(1, steps // 10) == 0:
            log(f"  step {i:>5} loss {float(loss):.3f}")
    log(f"  trained {cfg.name} ({param_count(params)} params) in {time.time()-t0:.1f}s "
        f"final loss {np.mean(losses[-20:]):.3f}")
    return params, losses


def train_mlp(cfg: MlpConfig, x: np.ndarray, y: np.ndarray, steps: int, batch: int, lr: float, log):
    key = jax.random.PRNGKey(hash(cfg.name) % (2**31))
    params = mlp_init(cfg, key)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, bx, by):
        loss, grads = jax.value_and_grad(lambda p: mlp_loss(cfg, p, bx, by))(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    rng = np.random.default_rng(7)
    t0 = time.time()
    loss = None
    for i in range(steps):
        idx = rng.integers(0, len(y), batch)
        params, opt, loss = step(params, opt, jnp.array(x[idx]), jnp.array(y[idx]))
        if i % max(1, steps // 5) == 0:
            log(f"  step {i:>5} loss {float(loss):.3f}")
    # train accuracy
    logits = mlp_forward(cfg, params, jnp.array(x[:1000]))
    acc = float((jnp.argmax(logits, -1) == jnp.array(y[:1000])).mean()) * 100
    log(f"  trained {cfg.name} in {time.time()-t0:.1f}s final loss {float(loss):.3f} "
        f"train acc {acc:.1f}%")
    return params


# ---------------------------------------------------------------------------
# Export
# ---------------------------------------------------------------------------


def export_model(out_dir: pathlib.Path, name: str, family: str, cfg, params: dict, extra: dict):
    mdir = out_dir / name
    mdir.mkdir(parents=True, exist_ok=True)
    tensors = {}
    for tname, val in params.items():
        arr = np.asarray(val, dtype="<f4")
        tensors[tname] = list(arr.shape)
        (mdir / f"{tname}.bin").write_bytes(arr.tobytes())
    manifest = {"name": name, "family": family, "tensors": tensors, **extra}
    (mdir / "manifest.json").write_text(json.dumps(manifest, indent=2))


def export_lm(out_dir, cfg: LmConfig, params, losses):
    arch = {
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "d_ff": cfg.d_ff,
        "max_seq": cfg.max_seq,
        "act": cfg.act,
        "parallel_residual": cfg.parallel_residual,
    }
    train_info = {"final_loss": float(np.mean(losses[-20:])), "steps": len(losses)}
    export_model(out_dir, cfg.name, "lm", cfg, params, {"lm": arch, "train": train_info})
    # parity bundle: fixed tokens + jax logits for the rust parity test
    tokens = np.arange(cfg.max_seq, dtype=np.int32) % cfg.vocab
    logits = np.asarray(lm_forward(cfg, params, jnp.array(tokens[None, :])))[0]
    mdir = out_dir / cfg.name
    (mdir / "parity_tokens.bin").write_bytes(tokens.astype("<i4").tobytes())
    (mdir / "parity_logits.bin").write_bytes(logits.astype("<f4").tobytes())
    # loss curve for EXPERIMENTS.md
    (mdir / "loss_curve.json").write_text(json.dumps([round(float(l), 4) for l in losses]))


def export_img(out_dir, cfg: MlpConfig, params, sample_x):
    arch = {
        "input_dim": cfg.input_dim,
        "hidden": list(cfg.hidden),
        "classes": cfg.classes,
        "act": cfg.act,
        "residual": cfg.residual,
    }
    export_model(out_dir, cfg.name, "img", cfg, params, {"img": arch})
    logits = np.asarray(mlp_forward(cfg, params, jnp.array(sample_x[:8])))
    mdir = out_dir / cfg.name
    (mdir / "parity_x.bin").write_bytes(np.asarray(sample_x[:8], "<f4").tobytes())
    (mdir / "parity_logits.bin").write_bytes(logits.astype("<f4").tobytes())


# LM training budget per model (steps, batch, lr)
LM_BUDGET = {
    "pico-70k": (700, 24, 3e-3),
    "pico-160k": (700, 24, 2e-3),
    "pico-410k": (500, 24, 2e-3),
    "pico-1m": (350, 16, 1.5e-3),
    "pico-2m": (250, 16, 1.5e-3),
    "pico-160k-opt": (700, 24, 2e-3),
    "pico-160k-gpt2": (700, 24, 2e-3),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/weights")
    ap.add_argument("--data", default="../artifacts/data")
    ap.add_argument("--models", default="all", help="comma list or 'all' / 'lm' / 'img'")
    ap.add_argument("--quick", action="store_true", help="tiny budgets (CI smoke)")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    data_dir = pathlib.Path(args.data)

    sel = args.models.split(",") if args.models not in ("all", "lm", "img") else None

    def want(name, family):
        if sel is not None:
            return name in sel
        if args.models == "lm":
            return family == "lm"
        if args.models == "img":
            return family == "img"
        return True

    log = print
    tokens = np.frombuffer((data_dir / "corpus_train.bin").read_bytes(), np.uint8).astype(np.int32)

    for name, cfg in LM_ZOO.items():
        if not want(name, "lm"):
            continue
        steps, batch, lr = LM_BUDGET[name]
        if args.quick:
            steps = 30
        log(f"training {name} ...")
        params, losses = train_lm(cfg, tokens, steps, batch, lr, log)
        export_lm(out_dir, cfg, params, losses)

    gx = np.frombuffer((data_dir / "glyphs_train_x.bin").read_bytes(), "<f4").reshape(-1, 256)
    gy = np.frombuffer((data_dir / "glyphs_train_y.bin").read_bytes(), np.uint8)
    for name, cfg in IMG_ZOO.items():
        if not want(name, "img"):
            continue
        steps = 60 if args.quick else 800
        log(f"training {name} ...")
        params = train_mlp(cfg, gx, gy, steps, 64, 1e-3, log)
        export_img(out_dir, cfg, params, gx)

    log("zoo export complete")


if __name__ == "__main__":
    main()
