"""Synthetic datasets (the WikiText2 / ImageNet substitutes; DESIGN.md §2).

- Corpus: an order-1 Markov chain over a Zipf-weighted 64-symbol
  alphabet. Structured enough that a trained pico-LM reaches PPL far
  below the uniform baseline, random enough that quantization damage is
  measurable.
- Glyphs: 16×16 grayscale "characters" — a class prototype of strokes
  plus per-sample jitter and noise.

Everything is deterministic from fixed seeds, and both splits are
written to artifacts/data/ so the rust side sees identical bytes.
"""

import argparse
import pathlib

import numpy as np

VOCAB = 64
GLYPH_SIDE = 16
GLYPH_CLASSES = 10


def make_corpus(length: int, seed: int, structure_seed: int = 0) -> np.ndarray:
    """Zipf–Markov byte stream with tokens in [0, VOCAB).

    The *language structure* (transition table) comes from
    `structure_seed` and is SHARED between train and val splits; `seed`
    only drives the sampling path — otherwise val would be a different
    language and perplexity meaningless.
    """
    rng = np.random.default_rng(seed)
    # Zipf marginal
    weights = 1.0 / np.arange(1, VOCAB + 1)
    weights /= weights.sum()
    # per-state transition: 4 preferred successors at 75% total mass
    succ = np.random.default_rng(structure_seed).integers(0, VOCAB, size=(VOCAB, 4))
    out = np.empty(length, dtype=np.uint8)
    state = 0
    stick = rng.random(length)
    pick = rng.integers(0, 4, size=length)
    zipf_draws = rng.choice(VOCAB, size=length, p=weights)
    for i in range(length):
        if stick[i] < 0.75:
            state = succ[state, pick[i]]
        else:
            state = zipf_draws[i]
        out[i] = state
    return out


def glyph_prototypes(proto_seed: int = 0) -> np.ndarray:
    """Class prototypes of 3 strokes each — FIXED across splits so the
    task is learnable (train and test share the class definitions)."""
    rng = np.random.default_rng(proto_seed)
    side = GLYPH_SIDE
    protos = np.zeros((GLYPH_CLASSES, side, side), np.float32)
    for c in range(GLYPH_CLASSES):
        for _ in range(3):
            # random stroke: line segment with thickness 1
            x0, y0 = rng.integers(2, side - 2, 2)
            angle = rng.random() * np.pi
            length = rng.integers(5, side - 2)
            for t in np.linspace(0, 1, 2 * length):
                x = int(round(x0 + np.cos(angle) * t * length))
                y = int(round(y0 + np.sin(angle) * t * length))
                if 0 <= x < side and 0 <= y < side:
                    protos[c, y, x] = 1.0
    return protos


def make_glyphs(n: int, seed: int, proto_seed: int = 0):
    """Glyph images: shared class prototypes + per-sample jitter/noise."""
    rng = np.random.default_rng(seed)
    protos = glyph_prototypes(proto_seed)
    side = GLYPH_SIDE
    xs = np.empty((n, side * side), np.float32)
    ys = np.empty(n, np.uint8)
    for i in range(n):
        c = i % GLYPH_CLASSES
        img = protos[c].copy()
        # jitter: roll by up to 1 pixel
        img = np.roll(img, rng.integers(-1, 2), axis=0)
        img = np.roll(img, rng.integers(-1, 2), axis=1)
        img += rng.normal(0, 0.25, img.shape).astype(np.float32)
        xs[i] = img.reshape(-1)
        ys[i] = c
    return xs, ys


def write_all(out_dir: pathlib.Path, train_len: int, val_len: int, n_train: int, n_test: int):
    out_dir.mkdir(parents=True, exist_ok=True)
    train = make_corpus(train_len, seed=1)
    val = make_corpus(val_len, seed=2)
    (out_dir / "corpus_train.bin").write_bytes(train.tobytes())
    (out_dir / "corpus_val.bin").write_bytes(val.tobytes())
    gx, gy = make_glyphs(n_train, seed=3)
    tx, ty = make_glyphs(n_test, seed=4)
    (out_dir / "glyphs_train_x.bin").write_bytes(gx.astype("<f4").tobytes())
    (out_dir / "glyphs_train_y.bin").write_bytes(gy.tobytes())
    (out_dir / "glyphs_test_x.bin").write_bytes(tx.astype("<f4").tobytes())
    (out_dir / "glyphs_test_y.bin").write_bytes(ty.tobytes())
    print(
        f"wrote corpus train={len(train)} val={len(val)}, "
        f"glyphs train={len(gy)} test={len(ty)} to {out_dir}"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/data")
    ap.add_argument("--train-len", type=int, default=400_000)
    ap.add_argument("--val-len", type=int, default=80_000)
    ap.add_argument("--glyphs-train", type=int, default=4000)
    ap.add_argument("--glyphs-test", type=int, default=1000)
    args = ap.parse_args()
    write_all(
        pathlib.Path(args.out), args.train_len, args.val_len, args.glyphs_train, args.glyphs_test
    )


if __name__ == "__main__":
    main()
