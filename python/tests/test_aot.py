"""AOT path tests: HLO text emission, parseability, kernel artifact
round-trip through the XLA client (the same path the rust runtime uses).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile.aot import export_qmatmul, to_hlo_text
from compile.kernels.ref import qmatmul_ref
from compile.model import LmConfig, lm_forward, lm_init


def compile_and_run(hlo_text: str, args):
    """Round-trip: HLO text -> XlaComputation -> local client -> execute.
    Mirrors rust/src/runtime/mod.rs."""
    comp = xc._xla.hlo_module_from_text(hlo_text)
    # re-serialize through the text parser like the rust loader does
    client = xc._xla.get_tfrt_cpu_client()
    xcomp = xc.XlaComputation(comp.as_serialized_hlo_module_proto())
    exe = client.compile(xcomp.as_serialized_hlo_module_proto())
    bufs = [client.buffer_from_pyval(a) for a in args]
    out = exe.execute(bufs)
    return [np.asarray(o) for o in out]


class TestHloText:
    def test_simple_fn_emits_parseable_text(self):
        def fn(a, b):
            return (a @ b + 1.0,)

        spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
        text = to_hlo_text(jax.jit(fn).lower(spec, spec))
        assert "HloModule" in text
        # parse back via the same text parser the rust loader uses
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None

    def test_lm_forward_lowers(self):
        cfg = LmConfig("t", vocab=32, d_model=16, n_layers=1, n_heads=2, d_ff=32, max_seq=8)
        params = lm_init(cfg, jax.random.PRNGKey(0))
        names = sorted(params.keys())

        def fwd(tokens, *flat):
            p = dict(zip(names, flat))
            return (lm_forward(cfg, p, tokens.astype(jnp.int32)),)

        tok = jax.ShapeDtypeStruct((1, 8), jnp.float32)
        specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names]
        text = to_hlo_text(jax.jit(fwd).lower(tok, *specs))
        assert "HloModule" in text
        assert xc._xla.hlo_module_from_text(text) is not None

    def test_pallas_kernel_lowers_and_runs(self, tmp_path):
        entry = export_qmatmul(tmp_path, m=8, k=64, n=8, tile=32, p_inner=16, p_outer=17)
        text = (tmp_path / f"{entry['name']}.hlo.txt").read_text()
        assert "HloModule" in text
        rng = np.random.default_rng(0)
        x = rng.integers(0, 255, (8, 64), dtype=np.int32)
        w = rng.integers(-7, 8, (64, 8), dtype=np.int32)
        try:
            outs = compile_and_run(text, [x, w])
        except Exception as e:  # pragma: no cover - client API drift
            pytest.skip(f"local XLA client API unavailable: {e}")
        ref = np.asarray(qmatmul_ref(x, w, 32, 16, 17))
        np.testing.assert_array_equal(outs[0].reshape(8, 8), ref)
