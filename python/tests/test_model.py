"""L2 model tests: shapes, causality, training signal, quantized path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    IMG_ZOO,
    LM_ZOO,
    LmConfig,
    MlpConfig,
    QuantSpec,
    lm_forward,
    lm_init,
    lm_loss,
    mlp_forward,
    mlp_init,
    mlp_loss,
    param_count,
    quant_linear_kernel,
)

TINY = LmConfig("tiny", vocab=32, d_model=16, n_layers=2, n_heads=2, d_ff=32, max_seq=16)


@pytest.fixture(scope="module")
def tiny_params():
    return lm_init(TINY, jax.random.PRNGKey(0))


class TestLmForward:
    def test_shapes(self, tiny_params):
        toks = jnp.arange(16, dtype=jnp.int32)[None, :] % 32
        logits = lm_forward(TINY, tiny_params, toks)
        assert logits.shape == (1, 16, 32)
        assert bool(jnp.isfinite(logits).all())

    def test_causality(self, tiny_params):
        a = jnp.array([[1, 2, 3, 4, 5]], jnp.int32)
        b = jnp.array([[1, 2, 3, 4, 31]], jnp.int32)
        la = lm_forward(TINY, tiny_params, a)
        lb = lm_forward(TINY, tiny_params, b)
        np.testing.assert_allclose(np.asarray(la[0, :4]), np.asarray(lb[0, :4]), atol=1e-5)

    def test_parallel_residual_differs(self, tiny_params):
        seq_cfg = LmConfig("t2", vocab=32, d_model=16, n_layers=2, n_heads=2, d_ff=32,
                           max_seq=16, parallel_residual=False)
        toks = jnp.arange(8, dtype=jnp.int32)[None, :]
        la = lm_forward(TINY, tiny_params, toks)
        lb = lm_forward(seq_cfg, tiny_params, toks)
        assert float(jnp.abs(la - lb).max()) > 1e-6

    def test_param_specs_cover_params(self, tiny_params):
        spec_names = {n for n, _ in TINY.param_specs()}
        assert spec_names == set(tiny_params.keys())
        for name, shape in TINY.param_specs():
            assert tiny_params[name].shape == shape, name

    def test_zoo_configs_valid(self):
        for name, cfg in LM_ZOO.items():
            assert cfg.d_model % cfg.n_heads == 0, name
            assert cfg.d_ff == 4 * cfg.d_model, name


class TestTraining:
    def test_loss_decreases(self):
        rng = np.random.default_rng(0)
        # deterministic-ish data: token t+1 = (t*2) % 32
        seqs = []
        for _ in range(8):
            start = rng.integers(0, 32)
            s = [start]
            for _ in range(15):
                s.append((s[-1] * 2 + 1) % 32)
            seqs.append(s)
        batch = jnp.array(seqs, jnp.int32)
        params = lm_init(TINY, jax.random.PRNGKey(1))
        loss0 = float(lm_loss(TINY, params, batch))
        grad_fn = jax.jit(jax.value_and_grad(lambda p: lm_loss(TINY, p, batch)))
        for _ in range(40):
            loss, g = grad_fn(params)
            params = {k: params[k] - 0.05 * g[k] for k in params}
        loss1 = float(loss)
        assert loss1 < loss0 * 0.7, f"{loss0} -> {loss1}"

    def test_mlp_loss_decreases(self):
        cfg = MlpConfig("t", input_dim=16, hidden=(24,), classes=4)
        rng = np.random.default_rng(1)
        x = rng.normal(size=(64, 16)).astype(np.float32)
        y = (x[:, 0] > 0).astype(np.int32) + 2 * (x[:, 1] > 0).astype(np.int32)
        params = mlp_init(cfg, jax.random.PRNGKey(2))
        loss0 = float(mlp_loss(cfg, params, jnp.array(x), jnp.array(y)))
        grad_fn = jax.jit(jax.value_and_grad(lambda p: mlp_loss(cfg, p, jnp.array(x), jnp.array(y))))
        for _ in range(60):
            loss, g = grad_fn(params)
            params = {k: params[k] - 0.1 * g[k] for k in params}
        assert float(loss) < loss0 * 0.5

    def test_img_zoo_configs(self):
        for name, cfg in IMG_ZOO.items():
            assert cfg.input_dim == 256, name
            assert cfg.classes == 10, name


class TestQuantizedPath:
    def test_quant_linear_approximates_float(self):
        rng = np.random.default_rng(3)
        m, k, n = 16, 64, 8
        x = rng.normal(size=(m, k)).astype(np.float32)
        w_float = rng.normal(size=(k, n)).astype(np.float32) * 0.2
        # simple symmetric weight quant at 8 bits per column
        scales = np.abs(w_float).max(axis=0) / 127.0
        codes = np.clip(np.round(w_float / scales), -127, 127).astype(np.int32)
        x_scale = float(np.abs(x).max() * 2 / 255.0)
        spec = QuantSpec(act_bits=8, tile=32, p_inner=24, p_outer=26, block_m=8, block_n=8)
        y = np.asarray(
            quant_linear_kernel(
                jnp.array(x), jnp.array(codes), jnp.array(scales.astype(np.float32)),
                x_scale, 128, spec,
            )
        )
        y_ref = x @ w_float
        err = np.abs(y - y_ref).max() / (np.abs(y_ref).max() + 1e-9)
        assert err < 0.05, f"relative error {err}"

    def test_zero_point_correction_exact(self):
        # integer identity: kernel-with-zp == shifted exact dot
        rng = np.random.default_rng(4)
        x_codes = rng.integers(0, 255, (8, 32), dtype=np.int32)
        w = rng.integers(-7, 8, (32, 8), dtype=np.int32)
        zp = 77
        from compile.kernels.qmatmul import dequantize, qmatmul

        acc = qmatmul(jnp.array(x_codes), jnp.array(w), tile=32, p_inner=30, p_outer=31,
                      block_m=8, block_n=8)
        y = np.asarray(dequantize(acc, jnp.ones(8), 1.0, zp, jnp.array(w.sum(axis=0))))
        ref = (x_codes.astype(np.int64) - zp) @ w.astype(np.int64)
        np.testing.assert_allclose(y, ref.astype(np.float32), rtol=0, atol=0)

    def test_param_count(self, tiny_params):
        n = param_count(tiny_params)
        specs = TINY.param_specs()
        assert n == sum(int(np.prod(s)) for _, s in specs)
