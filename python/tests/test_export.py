"""Export-path tests: the tensor/manifest format contract with the rust
loader, and the training-budget table's consistency with the zoo."""

import json
import pathlib

import jax
import numpy as np
import pytest

from compile.model import IMG_ZOO, LM_ZOO, LmConfig, lm_forward, lm_init, param_count
from compile.train import LM_BUDGET, export_lm, export_model


class TestBudgets:
    def test_every_lm_has_a_budget(self):
        for name in LM_ZOO:
            assert name in LM_BUDGET, name

    def test_zoo_param_ladder_monotone(self):
        ladder = ["pico-70k", "pico-160k", "pico-410k", "pico-1m", "pico-2m"]
        counts = []
        for name in ladder:
            cfg = LM_ZOO[name]
            params = lm_init(cfg, jax.random.PRNGKey(0))
            counts.append(param_count(params))
        assert counts == sorted(counts), counts
        # names roughly match the counts
        assert 50_000 < counts[0] < 100_000
        assert 1_500_000 < counts[-1] < 3_000_000


class TestExportFormat:
    @pytest.fixture(scope="class")
    def exported(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("zoo")
        cfg = LmConfig("tiny-exp", vocab=32, d_model=16, n_layers=1, n_heads=2,
                       d_ff=64, max_seq=8)
        params = lm_init(cfg, jax.random.PRNGKey(3))
        export_lm(out, cfg, params, losses=[3.0, 2.5, 2.0])
        return out / "tiny-exp", cfg, params

    def test_manifest_lists_all_tensors(self, exported):
        mdir, cfg, params = exported
        man = json.loads((mdir / "manifest.json").read_text())
        assert man["family"] == "lm"
        assert set(man["tensors"].keys()) == set(params.keys())
        assert man["lm"]["d_ff"] == 64
        assert man["train"]["steps"] == 3

    def test_tensors_roundtrip_little_endian(self, exported):
        mdir, cfg, params = exported
        man = json.loads((mdir / "manifest.json").read_text())
        for name, shape in man["tensors"].items():
            raw = np.frombuffer((mdir / f"{name}.bin").read_bytes(), "<f4")
            assert raw.size == int(np.prod(shape)), name
            np.testing.assert_allclose(raw.reshape(shape), np.asarray(params[name]), rtol=0,
                                       atol=0)

    def test_parity_bundle_matches_forward(self, exported):
        mdir, cfg, params = exported
        tokens = np.frombuffer((mdir / "parity_tokens.bin").read_bytes(), "<i4")
        logits = np.frombuffer((mdir / "parity_logits.bin").read_bytes(), "<f4")
        expect = np.asarray(lm_forward(cfg, params, np.asarray(tokens)[None, :]))[0]
        np.testing.assert_allclose(logits.reshape(expect.shape), expect, atol=1e-5)

    def test_loss_curve_written(self, exported):
        mdir, _, _ = exported
        curve = json.loads((mdir / "loss_curve.json").read_text())
        assert curve == [3.0, 2.5, 2.0]


class TestGenericExport:
    def test_export_model_writes_every_tensor(self, tmp_path):
        params = {"a.w": np.ones((2, 3), np.float32), "a.b": np.zeros(2, np.float32)}
        export_model(tmp_path, "m", "img", None, params, {"img": {}})
        man = json.loads((tmp_path / "m" / "manifest.json").read_text())
        assert man["tensors"] == {"a.w": [2, 3], "a.b": [2]}
        assert (tmp_path / "m" / "a.w.bin").stat().st_size == 24

    def test_img_zoo_importable(self):
        assert set(IMG_ZOO) == {"glyph-mlp", "glyph-res", "glyph-bottleneck"}
