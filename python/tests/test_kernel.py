"""L1 kernel correctness: Pallas qmatmul vs the pure-jnp/numpy oracle.

Hypothesis sweeps shapes, tiles and register widths — the core
correctness signal for the quantized datapath.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.qmatmul import qmatmul, vmem_words
from compile.kernels.ref import (
    overflow_count_ref,
    qmatmul_exact,
    qmatmul_ref,
    wrap_twos_complement,
)


def random_codes(rng, m, k, n, act_bits=8, w_max=7):
    x = rng.integers(0, (1 << act_bits) - 1, (m, k), dtype=np.int32)
    w = rng.integers(-w_max, w_max + 1, (k, n), dtype=np.int32)
    return x, w


class TestWrap:
    def test_wrap_matches_int8_cast(self):
        v = np.arange(-1000, 1000, dtype=np.int64)
        w = np.asarray(wrap_twos_complement(v, 8))
        assert (w == v.astype(np.int8)).all()

    def test_wrap_matches_int16_cast(self):
        v = np.random.default_rng(0).integers(-(10**6), 10**6, 5000)
        w = np.asarray(wrap_twos_complement(v, 16))
        assert (w == v.astype(np.int16)).all()

    def test_wrap_identity_in_range(self):
        v = np.arange(-128, 128, dtype=np.int64)
        assert (np.asarray(wrap_twos_complement(v, 8)) == v).all()


class TestKernelVsRef:
    @pytest.mark.parametrize("tile,p_inner", [(32, 12), (64, 16), (128, 16), (64, 20)])
    def test_matches_ref_fixed_shapes(self, tile, p_inner):
        rng = np.random.default_rng(tile * 1000 + p_inner)
        m, k, n = 32, 256, 64
        p_outer = p_inner + int(np.ceil(np.log2(k // tile)))
        x, w = random_codes(rng, m, k, n)
        out = np.asarray(qmatmul(jnp.array(x), jnp.array(w), tile=tile, p_inner=p_inner,
                                 p_outer=p_outer))
        ref = np.asarray(qmatmul_ref(x, w, tile, p_inner, p_outer))
        np.testing.assert_array_equal(out, ref)

    @settings(max_examples=25, deadline=None)
    @given(
        mi=st.integers(1, 4),
        ki=st.integers(1, 6),
        ni=st.integers(1, 4),
        tile_i=st.sampled_from([1, 2, 4]),
        p_inner=st.integers(10, 24),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, mi, ki, ni, tile_i, p_inner, seed):
        m, n = 8 * mi, 8 * ni
        tile = 16 * tile_i
        k = tile * ki
        p_outer = min(31, p_inner + int(np.ceil(np.log2(max(1, k // tile)))))
        rng = np.random.default_rng(seed)
        x, w = random_codes(rng, m, k, n)
        out = np.asarray(
            qmatmul(jnp.array(x), jnp.array(w), tile=tile, p_inner=p_inner, p_outer=p_outer,
                    block_m=8, block_n=8)
        )
        ref = np.asarray(qmatmul_ref(x, w, tile, p_inner, p_outer))
        np.testing.assert_array_equal(out, ref)

    def test_wide_register_equals_exact(self):
        rng = np.random.default_rng(5)
        x, w = random_codes(rng, 16, 128, 32)
        out = np.asarray(qmatmul(jnp.array(x), jnp.array(w), tile=64, p_inner=30, p_outer=31,
                                 block_m=16, block_n=32))
        exact = qmatmul_exact(x, w)
        np.testing.assert_array_equal(out.astype(np.int64), exact)

    def test_narrow_register_wraps(self):
        # all-max weights overflow a 12-bit tile accumulator
        x = np.full((8, 64), 255, np.int32)
        w = np.full((64, 8), 7, np.int32)
        out = np.asarray(qmatmul(jnp.array(x), jnp.array(w), tile=64, p_inner=12, p_outer=12,
                                 block_m=8, block_n=8))
        exact = qmatmul_exact(x, w)
        assert (out.astype(np.int64) != exact).any(), "must wrap"
        assert overflow_count_ref(x, w, 64, 12, 12) > 0

    def test_safe_budget_never_wraps(self):
        # weights within the Eq.4/Eq.17 budget -> wrapped == exact
        rng = np.random.default_rng(6)
        k, tile, p, nbits = 128, 32, 14, 8
        budget = (2 ** (p - 1) - 1) / (2**nbits - 1)
        w = np.zeros((k, 16), np.int32)
        for col in range(16):
            pos = neg = 0.0
            for i in range(k):
                v = rng.integers(-5, 6)
                t = i // tile
                _ = t
                if v >= 0 and pos + v <= budget:
                    pos += v
                    w[i, col] = v
                elif v < 0 and neg - v <= budget:
                    neg -= v
                    w[i, col] = v
            if (i + 1) % tile == 0:
                pos = neg = 0.0
        x = rng.integers(0, 255, (8, k), dtype=np.int32)
        p_outer = p + int(np.ceil(np.log2(k // tile)))
        out = np.asarray(qmatmul(jnp.array(x), jnp.array(w), tile=tile, p_inner=p,
                                 p_outer=p_outer, block_m=8, block_n=16))
        np.testing.assert_array_equal(out.astype(np.int64), qmatmul_exact(x, w))
        assert overflow_count_ref(x, w, tile, p, p_outer) == 0

    def test_monolithic_is_tile_equals_k(self):
        rng = np.random.default_rng(7)
        x, w = random_codes(rng, 8, 64, 8)
        mono = np.asarray(qmatmul(jnp.array(x), jnp.array(w), tile=64, p_inner=16, p_outer=16,
                                  block_m=8, block_n=8))
        ref = np.asarray(qmatmul_ref(x, w, 64, 16, 16))
        np.testing.assert_array_equal(mono, ref)


class TestVmem:
    def test_vmem_budget_documented_blocks(self):
        # the DESIGN.md example: bm=bn=64, T=128 -> 64Ki words = 256 KiB
        words = vmem_words(64, 64, 128)
        assert words == (64 + 64) * 128 + 64 * 64
        assert words * 4 < 16 * 1024 * 1024, "fits VMEM with headroom"

    def test_kernel_rejects_bad_tile(self):
        x = jnp.zeros((8, 100), jnp.int32)
        w = jnp.zeros((100, 8), jnp.int32)
        with pytest.raises(AssertionError):
            qmatmul(x, w, tile=64, p_inner=16, p_outer=16)
