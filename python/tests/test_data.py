"""Dataset generator tests: determinism, ranges, structure."""

import numpy as np

from compile.data import GLYPH_CLASSES, GLYPH_SIDE, VOCAB, make_corpus, make_glyphs


class TestCorpus:
    def test_deterministic(self):
        a = make_corpus(5000, seed=1)
        b = make_corpus(5000, seed=1)
        np.testing.assert_array_equal(a, b)

    def test_different_seeds_differ(self):
        a = make_corpus(5000, seed=1)
        b = make_corpus(5000, seed=2)
        assert (a != b).any()

    def test_range_and_dtype(self):
        c = make_corpus(10_000, seed=3)
        assert c.dtype == np.uint8
        assert c.max() < VOCAB

    def test_zipf_structure(self):
        c = make_corpus(50_000, seed=4)
        counts = np.bincount(c, minlength=VOCAB)
        # the head symbols must be individually more frequent than the
        # tail (the Markov mixing flattens the marginal somewhat)
        assert counts[:8].mean() > 1.5 * counts[-32:].mean()

    def test_markov_predictability(self):
        # bigram entropy must be clearly below unigram entropy
        c = make_corpus(100_000, seed=5).astype(np.int64)
        uni = np.bincount(c, minlength=VOCAB) + 1e-9
        p_uni = uni / uni.sum()
        h_uni = -(p_uni * np.log(p_uni)).sum()
        big = np.zeros((VOCAB, VOCAB)) + 1e-9
        np.add.at(big, (c[:-1], c[1:]), 1)
        p_cond = big / big.sum(axis=1, keepdims=True)
        p_state = big.sum(axis=1) / big.sum()
        h_big = -(p_state[:, None] * p_cond * np.log(p_cond)).sum()
        assert h_big < 0.8 * h_uni, f"bigram {h_big:.2f} vs unigram {h_uni:.2f}"


class TestGlyphs:
    def test_shapes_and_labels(self):
        x, y = make_glyphs(200, seed=1)
        assert x.shape == (200, GLYPH_SIDE * GLYPH_SIDE)
        assert x.dtype == np.float32
        assert set(np.unique(y)) == set(range(GLYPH_CLASSES))

    def test_deterministic(self):
        x1, y1 = make_glyphs(50, seed=2)
        x2, y2 = make_glyphs(50, seed=2)
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)

    def test_classes_separable(self):
        # a nearest-class-mean classifier must beat chance comfortably —
        # otherwise the accuracy experiments would be meaningless
        x, y = make_glyphs(1000, seed=3)
        means = np.stack([x[y == c].mean(axis=0) for c in range(GLYPH_CLASSES)])
        tx, ty = make_glyphs(500, seed=4)
        d = ((tx[:, None, :] - means[None, :, :]) ** 2).sum(-1)
        pred = d.argmin(axis=1)
        acc = (pred == ty).mean()
        assert acc > 0.6, f"nearest-mean accuracy {acc}"
